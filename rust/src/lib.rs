//! # neon-morph
//!
//! Production reproduction of *“Fast Implementation of Morphological
//! Filtering Using ARM NEON Extension”* (Limonova, Terekhin, Nikolaev,
//! Arlazarov — CS.DC 2020) as a three-layer Rust + JAX + Pallas stack.
//!
//! The paper speeds up erosion/dilation with rectangular structuring
//! elements by (1) exploiting separability into 1-D passes, (2) choosing
//! per pass between the van Herk/Gil-Werman algorithm (O(1) comparisons
//! per pixel) and a *linear* algorithm (O(w) comparisons but perfectly
//! SIMD-parallel), with a measured crossover (w_y⁰ = 69, w_x⁰ = 59 on
//! Exynos 5422), and (3) fast SIMD matrix transpose (8×8.16 / 16×16.8
//! vtrn networks) so the vertical pass can reuse the horizontal code.
//!
//! Crate layout (see `DESIGN.md` for the full inventory):
//!
//! * [`image`] — stride-aware `u8`/`u16` image containers, the
//!   borrowed [`image::ImageView`]/[`image::ImageViewMut`] types every
//!   kernel operates on, PGM I/O, synthetic workload generators (the
//!   paper's 800×600 gray input).
//! * [`neon`] — an ARM NEON *simulator*: 128-bit register types plus the
//!   instruction subset the paper uses, behind a [`neon::Backend`] trait
//!   with a fast native implementation and a counting implementation
//!   that records the exact instruction mix (the substituted hardware
//!   substrate — we have no Exynos 5422; see DESIGN.md §Substitutions).
//! * [`costmodel`] — per-instruction-class latencies (Cortex-A15-like)
//!   that price an instruction mix in nanoseconds, reproducing the
//!   paper's Table 1 / Fig 3 / Fig 4 scales and crossovers.
//! * [`transpose`] — scalar, cache-blocked and NEON 8×8.16 / 16×16.8
//!   tile transposes (§4), plus whole-image tiled transpose.
//! * [`morphology`] — the paper's algorithm suite: naive 2-D baseline,
//!   vHGW and linear 1-D passes (scalar + SIMD), separable composition,
//!   the §5.3 hybrid dispatch, and derived operations.  Every pass is
//!   generic over [`morphology::MorphPixel`], so the same code filters
//!   `Image<u8>` (16 SIMD lanes/op, 16×16.8 transpose tiles) and
//!   `Image<u16>` (8 lanes/op, 8×8.16 tiles) — the two depths the
//!   paper's §4 transpose shapes exist for.  `morphology::parallel`
//!   adds intra-image **band-sharding**: native executions split each
//!   pass into row bands with `w-1` halos (tile-aligned column stripes
//!   for the vertical transpose sandwich) and run the bands on a
//!   shared worker pool, bit-identical to the sequential path.
//! * [`runtime`] — PJRT bridge executing the AOT-lowered JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`) from Rust; python is never on the
//!   request path.
//! * [`coordinator`] — the serving layer: a **staged pipeline**
//!   (admit → ingress → plan-resolve → execute lanes → reply) over
//!   bounded channels, with router, dynamic batcher, admission-only
//!   backpressure and per-stage metrics.  Requests carry depth-tagged
//!   payloads (`u8`/`u16`); batch keys include the dtype, and u16 work
//!   always routes to the native engine (AOT artifacts are u8-only).
//!
//! ## Plan–execute contract
//!
//! The public API is **describe once, resolve once, run many**:
//!
//! * [`morphology::FilterSpec`] — a depth-generic, heap-free
//!   (`Copy + Eq + Hash`) description: an op chain
//!   ([`morphology::FilterOp`] — erode/dilate plus every derived op,
//!   lowered to primitive erode/dilate/subtract steps), one `w_x × w_y`
//!   SE, a [`morphology::MorphConfig`] and an optional
//!   [`morphology::Roi`].
//! * [`FilterSpec::plan`](morphology::FilterSpec::plan) resolves the
//!   spec against a pixel depth and image shape into a
//!   [`morphology::FilterPlan`]: hybrid method choices, §5.2.1
//!   sandwich decisions and the cost-model band count are fixed once,
//!   and a scratch arena (intermediate slot images, the rows→cols
//!   buffer, transpose-sandwich buffers, replicate staging, per-band
//!   vHGW `R` slots) is preallocated.
//! * [`FilterPlan::run`](morphology::FilterPlan::run) /
//!   [`run_owned`](morphology::FilterPlan::run_owned) execute with the
//!   zero-copy `_into` kernels, reusing the arena: a reused plan's Nth
//!   run allocates **no intermediate-image bytes** for any method —
//!   vHGW's "2× extra memory" `R` buffer included
//!   (`rust/tests/zero_copy_alloc.rs`).
//!
//! **Position independence.** A plan's resolution depends on the ROI's
//! haloed-block *shape*, never its origin:
//! [`FilterPlan::run_at`](morphology::FilterPlan::run_at) takes the
//! block origin at call time, so one plan serves every *interior*
//! position of a same-shape crop sweep (edge-clamped positions resolve
//! their own clamped geometry and keep their own plans).
//! [`FilterSpec::canonical_for`](morphology::FilterSpec::canonical_for)
//! is the matching cache-key rule — interior ROIs are keyed at the
//! canonical anchor — so the engine plan cache resolves a sweep
//! exactly once (hit-count asserted in `runtime::engine` tests and
//! gated in CI via `BENCH_serve.json`).
//!
//! ### Fused batch execution
//!
//! A batch of `n` same-shape images under one spec runs as **one**
//! banded execution:
//! [`FilterSpec::plan_fused`](morphology::FilterSpec::plan_fused)
//! resolves a [`morphology::FusedPlan`] whose
//! [`run_batch`](morphology::FusedPlan::run_batch) treats the batch as
//! a virtual `n·h`-row image — band cuts may land anywhere in the fused
//! extent (snapped image-locally, so a seam cut is always legal), but
//! every per-image row segment halos against its **own** image, never a
//! neighbor's rows.  The result is bit-identical, image for image, to
//! running the per-image [`morphology::FilterPlan`] `n` times
//! (`rust/tests/fused_batch.rs`; geometry mirrored in
//! `python/tests/test_fused_geometry.py`) while paying the fork-join
//! and per-band overhead **once per pass instead of once per image** —
//! pure overhead recovery that grows with the batch.  The fused arena
//! is a high-water mark (`reserve(n)` grows, smaller batches reuse);
//! full-image specs only — ROI and bare-transpose specs return
//! [`PlanError`](morphology::PlanError) and are served per-image.  The
//! coordinator routes every multi-request same-key batch through this
//! path (`fused_batches`/`fused_requests` in
//! [`coordinator::metrics::Snapshot`]), and `BENCH_serve.json` gates
//! the modeled fused:sequential ratio at batch 64 ≥ 1.
//!
//! Every layer speaks specs: the coordinator's depth-erased
//! [`coordinator::Coordinator::submit`]`(FilterSpec, ImagePayload)`
//! groups requests by the typed
//! [`coordinator::request::BatchKey`] (dtype + shape + op chain +
//! config + ROI *shape*) and each worker's native engine caches one
//! resolved plan per canonical `(spec, shape)`; the CLI's `filter --op
//! ... --roi ...` builds one spec (any op or comma-chain composes with
//! `--roi`).
//!
//! ### Streaming-serving contract
//!
//! Serving is a **staged pipeline** behind one lossless rule: *sheds
//! happen only at admission, and every admitted request is answered
//! exactly once.*  [`coordinator::Coordinator::submit`] is
//! fire-and-wait (one ticket, one reply channel).  For serving-rate
//! producers, [`coordinator::Coordinator::stream`] /
//! [`coordinator::Coordinator::submit_many`] return a
//! [`coordinator::SubmitStream`]: `send` enqueues without blocking or
//! allocating a per-ticket channel, `recv`/`drain` yield responses in
//! **completion** order (match them by
//! [`coordinator::request::FilterResponse::id`]), and admission sheds
//! — a full pipeline, or an exhausted per-key budget
//! ([`coordinator::CoordinatorConfig::admission_budget`]) — are
//! counted on the stream rather than aborting it.  Past admission,
//! stage-to-stage handoffs **block** over bounded channels
//! ([`coordinator::CoordinatorConfig::stage_capacity`], deadline
//! backstop [`coordinator::CoordinatorConfig::stage_deadline`]), so
//! backpressure propagates stage to stage while queue pulls overlap
//! in-flight band execution; the plan-resolve stage **warms** each
//! request's plan on its execute lane ahead of the batch, so lanes
//! drain same-key runs (FIFO-aged so a hot key cannot starve others)
//! through one **pinned, position-independent plan**.
//! `plan_resolutions`/`plan_hits` meter the economy (each request is a
//! warm + an execute touch: `G` same-family requests score `1`
//! resolution + `2G − 1` hits) and per-stage depth/peak/blocked-send
//! counters in [`coordinator::metrics::Snapshot`] meter the pipeline;
//! a per-request band budget
//! ([`coordinator::CoordinatorConfig::max_bands_per_request`], default
//! `cores / workers`) keeps one giant request from monopolizing the
//! shared band pool.  A panic while serving is stage-local: the lane
//! rebuilds its engine and answers that request with an error, so
//! streams never hang on accepted work.  Streamed output is
//! bit-identical to per-ticket `submit`
//! (`rust/tests/streaming_serve.rs`, `rust/tests/pipeline_serve.rs`;
//! `examples/streaming_serve.rs` and `examples/pipeline_serve.rs` are
//! the end-to-end drivers).
//!
//! ### Scenario engines: RLE binary morphology + geodesic reconstruction
//!
//! Two first-class engines serve the document-imaging scenarios the
//! dense pipeline is a poor fit for:
//!
//! * **Run-length binary morphology** ([`morphology::RleImage`]).  A
//!   0/255 mask is per-row sorted foreground intervals; rect-SE
//!   erode/dilate become interval shrink/grow + `w_y`-way
//!   intersection/union, so work scales with *runs*, not pixels.
//!   [`morphology::Representation`] in [`morphology::MorphConfig`]
//!   selects the engine per spec: `Dense` (default), `Rle` (use
//!   intervals whenever the source is binary), or `Auto` — priced by
//!   [`costmodel::CostModel::rle_speedup`] from the source's measured
//!   density (the Bernoulli run census
//!   [`costmodel::runs_per_row`]), falling back to dense above the
//!   crossover density.  The dispatch is **whole-image plans only**
//!   (ROI plans stay dense) and always bit-identical to the dense path
//!   (`rust/tests/rle_geodesic.rs`; mirrored in
//!   `python/tests/test_rle_geodesic.py`); non-binary sources fall
//!   back silently.  `BENCH_rle.json` gates the modeled sparse-mask
//!   speedup and crossover density in CI.
//! * **Geodesic reconstruction** ([`morphology::FilterOp::Reconstruct`],
//!   library forms [`morphology::reconstruct_by_dilation`] /
//!   [`morphology::reconstruct_by_erosion`], primitives
//!   [`morphology::geodesic_dilate`] / [`morphology::geodesic_erode`]).
//!   A reconstruction spec plans like any other op
//!   ([`FilterPlan::run_reconstruct`](morphology::FilterPlan::run_reconstruct)
//!   iterates an arena-backed elementary sweep to the fixpoint,
//!   clamping against the mask each sweep and counting every executed
//!   sweep including the final proving one), and serves like any other
//!   request: [`coordinator::Coordinator::submit_with_marker`] /
//!   [`filter_spec_with_marker`](coordinator::Coordinator::filter_spec_with_marker)
//!   carry the second (marker) payload through the staged pipeline
//!   with the same plan-cache economy (`1` resolution + `2G − 1` hits
//!   per family) — CLI: `filter --op reconstruct --marker seed.pgm`,
//!   end-to-end driver `examples/document_mask.rs`.
//!
//! ### Migration notes (wrapper entry points)
//!
//! The historical *library* entry points survive as thin, bit-identical
//! wrappers over one-shot plans — `morphology::{erode, dilate,
//! erode_roi, dilate_roi}`, `morphology::parallel::{filter_native,
//! filter_roi, opening_native, …}`, and the backend-generic derived ops
//! (which run the *same lowered step sequence* sequentially, keeping
//! counted instruction mixes deterministic).  The *service* surface is
//! now spec-only: the string-op wrappers `Coordinator::filter` /
//! `filter_u16` are **gone** — parse the op name once with
//! [`FilterSpec::parse_op`](morphology::FilterSpec::parse_op) and call
//! [`coordinator::Coordinator::filter_spec`] / `submit` (unknown names
//! fail at parse time, before anything is enqueued).  Per-depth
//! `submit`/`submit_u16` are likewise gone — pass any
//! `Arc<Image<u8>>`/`Arc<Image<u16>>` straight to `submit` — and the
//! 0.3.0-deprecated panicking `FilterOutput::expect_u8`/`expect_u16`
//! accessors have been removed in favour of
//! `FilterOutput::into_u8()`/`into_u16()`.
//!
//! ## Zero-copy view contract
//!
//! Every kernel's canonical source argument is a borrowed
//! [`image::ImageView`] (`&Image` coerces through `From` at each call
//! site), and the 1-D passes have `_into` forms writing straight into
//! a caller-provided [`image::ImageViewMut`].  The ownership rules:
//!
//! * `ImageView` is `Copy`; arbitrarily many may alias the same pixels
//!   — overlapping *reads* (band halos) are plain shared borrows.
//! * `ImageViewMut` is unique; disjoint concurrent *writes* come in two
//!   shapes.  Row bands go through
//!   [`image::ImageViewMut::split_at_rows_mut`], which partitions the
//!   underlying storage at a row boundary.  Column stripes (the banded
//!   §4 transpose writes dest columns, which *interleave* in memory) go
//!   through [`image::ImageViewMut::split_cols_mut`], whose stripes
//!   share the parent's raw base pointer and rely on the contiguous,
//!   non-overlapping column plan — asserted at split time — for
//!   disjointness; `rust/tests/parallel_banding.rs` and
//!   `python/tests/test_transpose_bands.py` pin that plan geometry.
//!
//! This is what makes band-sharding zero-copy (no haloed-slab copy in,
//! no core-row stitch out — `rust/tests/zero_copy_alloc.rs` pins the
//! allocation budget) and what powers the region-of-interest API:
//! [`morphology::erode_roi`] / [`morphology::dilate_roi`] /
//! [`morphology::filter_roi`] compute exactly
//! `crop(filter(full), roi)` from a borrowed haloed sub-rectangle
//! ([`morphology::Roi`]; CLI: `filter --roi Y,X,H,W`).
//!
//! ## Band-sharded parallelism
//!
//! * Policy: [`morphology::Parallelism`] in [`morphology::MorphConfig`]
//!   (`Sequential` / `Fixed(n)` / `Auto`; default `Auto`).  `Auto`
//!   shards only when the cost model predicts ≥10% gain over
//!   sequential ([`costmodel::CostModel::plan_workers`]), so small
//!   images never touch the pool.
//! * Geometry: a rows-window band with output rows `[b0, b1)` *reads*
//!   input rows `[b0 - w/2, b1 + w/2) ∩ [0, h)` through an overlapping
//!   borrowed view and *writes* its disjoint split of the destination
//!   in place; the direct cols pass bands rows with zero halo; the
//!   §5.2.1 sandwich is banded **end-to-end** in
//!   [`morphology::MorphPixel::LANES`]-aligned bands: both §4 tile
//!   transposes shard over the same pool
//!   ([`morphology::parallel::transpose_image_banded_into`]; each
//!   source row band writes its zero-halo destination column stripe),
//!   with the middle rows pass striping the transposed buffer in
//!   place.  Standalone `FilterOp::Transpose` plans and the fused
//!   batch sandwich route through the same banded kernels.  Output is
//!   bit-identical to sequential for every pass × method × depth ×
//!   border (`rust/tests/parallel_banding.rs`).
//! * Cost model: compute scales ~1/P, the memory/bandwidth term does
//!   not ([`costmodel::CostModel::parallel_breakdown`]; the transpose
//!   analog is [`costmodel::CostModel::transpose_breakdown`], priced
//!   per tile network), so modeled speedup saturates at the
//!   memory-bandwidth ceiling; since the zero-copy executor the
//!   per-band overhead constant models only job dispatch (no staging
//!   fudge).  `Auto` demotes a standalone transpose to sequential
//!   whenever the fork cost outweighs the ~10% gain bar
//!   ([`costmodel::CostModel::plan_transpose_workers`]) — at the paper
//!   sizes it always does, which `bench gate` pins via the
//!   `auto_bands_*` headlines of `BENCH_transpose.json`.  The scaling
//!   sweep (`bench scaling`, `benches/scaling.rs`) emits
//!   `BENCH_scaling.json` and CI pins its saturation point (±10%)
//!   against `rust/benches/baselines/`, alongside the Fig-3, Fig-4,
//!   Table-1 and transpose headline ratios.
//!
//! ## Pixel-depth dispatch rules
//!
//! * Library calls: `erode`/`dilate`/`morphology` and every derived op
//!   accept `&Image<u8>` or `&Image<u16>`; the depth is inferred and
//!   every `PassMethod` × [`VerticalStrategy`] × simd combination works
//!   at both depths (differential-tested against the naive oracle in
//!   `rust/tests/differential_u16.rs`).
//! * The [`VerticalStrategy::Transpose`] sandwich dispatches the §4
//!   tile shape by depth: 16×16.8 for `u8`, 8×8.16 for `u16`.
//! * Service calls: [`coordinator::Coordinator::submit`] takes any
//!   depth-tagged [`coordinator::request::ImagePayload`]; results come
//!   back as [`coordinator::request::FilterOutput`] (`into_u8` /
//!   `into_u16`).
//! * Cost accounting: a u16 pass issues ~2× the vector instructions per
//!   pixel (8 lanes/op vs 16) and streams 2× the bytes; see
//!   [`costmodel::simd_lanes`].
//! * [`bench_harness`] — sweep drivers that regenerate every table and
//!   figure of the paper's evaluation (Table 1, Fig 3, Fig 4).

pub mod bench_harness;
pub mod coordinator;
pub mod costmodel;
pub mod image;
pub mod morphology;
pub mod neon;
pub mod runtime;
pub mod util;
pub mod transpose;

pub use image::{Image, ImageView, ImageViewMut};
pub use morphology::{
    Border, FilterOp, FilterPlan, FilterSpec, FusedPlan, MorphOp, MorphPixel, OpChain,
    Parallelism, PassMethod, PlanError, Representation, RleImage, Roi, VerticalStrategy,
};
