//! The [`Backend`] trait: compute-and-account intrinsics.
//!
//! Every algorithm in [`crate::morphology`] and [`crate::transpose`] is
//! written once, generic over `B: Backend`.  Each intrinsic method has a
//! default implementation that performs the architectural semantics (via
//! [`super::regs`]) and then calls [`Backend::record`].  The two
//! implementations differ only in `record`:
//!
//! * [`Native`]   — `record` is an empty `#[inline(always)]` body; LLVM
//!   erases all accounting and the lane loops vectorize on the host, so
//!   this is the real wall-clock implementation.
//! * [`Counting`] — `record` accumulates an [`InstrMix`] for the
//!   Exynos-5422 cost model ([`crate::costmodel`]).
//!
//! Scalar (non-SIMD) reference code uses the `scalar_*` helpers so its
//! instruction mix is accounted through the same funnel.

use super::counters::{InstrClass, InstrMix};
use super::regs::{self, U16x8, U32x2, U32x4, U64x2, U8x16};

/// Compute-and-account SIMD backend.  See module docs.
pub trait Backend {
    /// Record `n` executed instructions of class `class`.
    fn record(&mut self, class: InstrClass, n: u64);

    /// Record memory traffic in bytes (reads, writes) — every access.
    fn record_bytes(&mut self, read: u64, written: u64);

    /// Record unique DRAM-streamed bytes (each buffer counted once per
    /// sweep) — called once per pass by the algorithm with its true
    /// streaming footprint; drives the cost model's bandwidth term.
    fn record_stream(&mut self, read: u64, written: u64);

    // -- vector loads / stores ------------------------------------------

    #[inline(always)]
    fn vld1q_u8(&mut self, src: &[u8]) -> U8x16 {
        self.record(InstrClass::SimdLoad, 1);
        self.record_bytes(16, 0);
        regs::vld1q_u8(src)
    }

    /// `vld1q` at an arbitrary (unaligned) offset — §5.2.2's
    /// `vld1q_u8(src + x - wing + j)` pattern.
    #[inline(always)]
    fn vld1q_u8_unaligned(&mut self, src: &[u8]) -> U8x16 {
        self.record(InstrClass::SimdLoadUnaligned, 1);
        self.record_bytes(16, 0);
        regs::vld1q_u8(src)
    }

    #[inline(always)]
    fn vst1q_u8(&mut self, dst: &mut [u8], v: U8x16) {
        self.record(InstrClass::SimdStore, 1);
        self.record_bytes(0, 16);
        regs::vst1q_u8(dst, v);
    }

    #[inline(always)]
    fn vld1q_u16(&mut self, src: &[u16]) -> U16x8 {
        self.record(InstrClass::SimdLoad, 1);
        self.record_bytes(16, 0);
        regs::vld1q_u16(src)
    }

    /// `vld1q.16` at an arbitrary (unaligned) element offset — the u16
    /// counterpart of [`Backend::vld1q_u8_unaligned`] for the §5.2.2
    /// vertical pass at 16-bit depth.
    #[inline(always)]
    fn vld1q_u16_unaligned(&mut self, src: &[u16]) -> U16x8 {
        self.record(InstrClass::SimdLoadUnaligned, 1);
        self.record_bytes(16, 0);
        regs::vld1q_u16(src)
    }

    #[inline(always)]
    fn vst1q_u16(&mut self, dst: &mut [u16], v: U16x8) {
        self.record(InstrClass::SimdStore, 1);
        self.record_bytes(0, 16);
        regs::vst1q_u16(dst, v);
    }

    #[inline(always)]
    fn vdupq_n_u8(&mut self, v: u8) -> U8x16 {
        self.record(InstrClass::SimdPermute, 1);
        regs::vdupq_n_u8(v)
    }

    // -- vector min / max -----------------------------------------------

    #[inline(always)]
    fn vminq_u8(&mut self, a: U8x16, b: U8x16) -> U8x16 {
        self.record(InstrClass::SimdMinMax, 1);
        regs::vminq_u8(a, b)
    }

    #[inline(always)]
    fn vmaxq_u8(&mut self, a: U8x16, b: U8x16) -> U8x16 {
        self.record(InstrClass::SimdMinMax, 1);
        regs::vmaxq_u8(a, b)
    }

    #[inline(always)]
    fn vminq_u16(&mut self, a: U16x8, b: U16x8) -> U16x8 {
        self.record(InstrClass::SimdMinMax, 1);
        regs::vminq_u16(a, b)
    }

    #[inline(always)]
    fn vmaxq_u16(&mut self, a: U16x8, b: U16x8) -> U16x8 {
        self.record(InstrClass::SimdMinMax, 1);
        regs::vmaxq_u16(a, b)
    }

    // -- permutations -----------------------------------------------------

    #[inline(always)]
    fn vtrnq_u8(&mut self, a: U8x16, b: U8x16) -> (U8x16, U8x16) {
        self.record(InstrClass::SimdPermute, 1);
        regs::vtrnq_u8(a, b)
    }

    #[inline(always)]
    fn vtrnq_u16(&mut self, a: U16x8, b: U16x8) -> (U16x8, U16x8) {
        self.record(InstrClass::SimdPermute, 1);
        regs::vtrnq_u16(a, b)
    }

    #[inline(always)]
    fn vtrnq_u32(&mut self, a: U32x4, b: U32x4) -> (U32x4, U32x4) {
        self.record(InstrClass::SimdPermute, 1);
        regs::vtrnq_u32(a, b)
    }

    #[inline(always)]
    fn vtrnq_u64(&mut self, a: U64x2, b: U64x2) -> (U64x2, U64x2) {
        self.record(InstrClass::SimdPermute, 1);
        regs::vtrnq_u64(a, b)
    }

    #[inline(always)]
    fn vget_low_u32(&mut self, a: U32x4) -> U32x2 {
        self.record(InstrClass::SimdCombine, 1);
        regs::vget_low_u32(a)
    }

    #[inline(always)]
    fn vget_high_u32(&mut self, a: U32x4) -> U32x2 {
        self.record(InstrClass::SimdCombine, 1);
        regs::vget_high_u32(a)
    }

    #[inline(always)]
    fn vcombine_u32(&mut self, lo: U32x2, hi: U32x2) -> U32x4 {
        self.record(InstrClass::SimdCombine, 1);
        regs::vcombine_u32(lo, hi)
    }

    // -- reinterprets (free auxiliaries, §4) -------------------------------

    #[inline(always)]
    fn reinterpret_u32_u16(&mut self, v: U16x8) -> U32x4 {
        self.record(InstrClass::SimdReinterpret, 1);
        regs::reinterpret_u32_u16(v)
    }

    #[inline(always)]
    fn reinterpret_u16_u32(&mut self, v: U32x4) -> U16x8 {
        self.record(InstrClass::SimdReinterpret, 1);
        regs::reinterpret_u16_u32(v)
    }

    #[inline(always)]
    fn reinterpret_u16_u8(&mut self, v: U8x16) -> U16x8 {
        self.record(InstrClass::SimdReinterpret, 1);
        regs::reinterpret_u16_u8(v)
    }

    #[inline(always)]
    fn reinterpret_u8_u16(&mut self, v: U16x8) -> U8x16 {
        self.record(InstrClass::SimdReinterpret, 1);
        regs::reinterpret_u8_u16(v)
    }

    #[inline(always)]
    fn reinterpret_u32_u8(&mut self, v: U8x16) -> U32x4 {
        self.record(InstrClass::SimdReinterpret, 1);
        regs::reinterpret_u32_u8(v)
    }

    #[inline(always)]
    fn reinterpret_u8_u32(&mut self, v: U32x4) -> U8x16 {
        self.record(InstrClass::SimdReinterpret, 1);
        regs::reinterpret_u8_u32(v)
    }

    #[inline(always)]
    fn reinterpret_u64_u8(&mut self, v: U8x16) -> U64x2 {
        self.record(InstrClass::SimdReinterpret, 1);
        regs::reinterpret_u64_u8(v)
    }

    #[inline(always)]
    fn reinterpret_u8_u64(&mut self, v: U64x2) -> U8x16 {
        self.record(InstrClass::SimdReinterpret, 1);
        regs::reinterpret_u8_u64(v)
    }

    // -- scalar accounting (for the non-SIMD reference implementations) --

    #[inline(always)]
    fn scalar_load_u8(&mut self, src: &[u8], idx: usize) -> u8 {
        self.record(InstrClass::ScalarLoad, 1);
        self.record_bytes(1, 0);
        src[idx]
    }

    #[inline(always)]
    fn scalar_store_u8(&mut self, dst: &mut [u8], idx: usize, v: u8) {
        self.record(InstrClass::ScalarStore, 1);
        self.record_bytes(0, 1);
        dst[idx] = v;
    }

    #[inline(always)]
    fn scalar_load_u16(&mut self, src: &[u16], idx: usize) -> u16 {
        self.record(InstrClass::ScalarLoad, 1);
        self.record_bytes(2, 0);
        src[idx]
    }

    #[inline(always)]
    fn scalar_store_u16(&mut self, dst: &mut [u16], idx: usize, v: u16) {
        self.record(InstrClass::ScalarStore, 1);
        self.record_bytes(0, 2);
        dst[idx] = v;
    }

    #[inline(always)]
    fn scalar_min_u8(&mut self, a: u8, b: u8) -> u8 {
        self.record(InstrClass::ScalarCmp, 1);
        a.min(b)
    }

    #[inline(always)]
    fn scalar_max_u8(&mut self, a: u8, b: u8) -> u8 {
        self.record(InstrClass::ScalarCmp, 1);
        a.max(b)
    }

    #[inline(always)]
    fn scalar_min_u16(&mut self, a: u16, b: u16) -> u16 {
        self.record(InstrClass::ScalarCmp, 1);
        a.min(b)
    }

    #[inline(always)]
    fn scalar_max_u16(&mut self, a: u16, b: u16) -> u16 {
        self.record(InstrClass::ScalarCmp, 1);
        a.max(b)
    }

    /// Loop / index-arithmetic overhead: `n` scalar ALU instructions.
    #[inline(always)]
    fn scalar_overhead(&mut self, n: u64) {
        self.record(InstrClass::ScalarAlu, n);
    }
}

/// Full-speed backend: accounting compiles away entirely.
#[derive(Clone, Copy, Debug, Default)]
pub struct Native;

impl Backend for Native {
    #[inline(always)]
    fn record(&mut self, _class: InstrClass, _n: u64) {}

    #[inline(always)]
    fn record_bytes(&mut self, _read: u64, _written: u64) {}

    #[inline(always)]
    fn record_stream(&mut self, _read: u64, _written: u64) {}
}

/// Accounting backend: accumulates the instruction mix.
#[derive(Clone, Debug, Default)]
pub struct Counting {
    pub mix: InstrMix,
}

impl Counting {
    pub fn new() -> Self {
        Self::default()
    }

    /// Snapshot for regional accounting via [`InstrMix::since`].
    pub fn snapshot(&self) -> InstrMix {
        self.mix
    }
}

impl Backend for Counting {
    #[inline(always)]
    fn record(&mut self, class: InstrClass, n: u64) {
        self.mix.bump(class, n);
    }

    #[inline(always)]
    fn record_bytes(&mut self, read: u64, written: u64) {
        self.mix.bytes_read += read;
        self.mix.bytes_written += written;
    }

    #[inline(always)]
    fn record_stream(&mut self, read: u64, written: u64) {
        self.mix.stream_read += read;
        self.mix.stream_written += written;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counting_backend_accounts() {
        let mut b = Counting::new();
        let data: Vec<u8> = (0..32).collect();
        let v = b.vld1q_u8(&data);
        let w = b.vld1q_u8(&data[16..]);
        let m = b.vminq_u8(v, w);
        let mut out = vec![0u8; 16];
        b.vst1q_u8(&mut out, m);
        assert_eq!(b.mix.get(InstrClass::SimdLoad), 2);
        assert_eq!(b.mix.get(InstrClass::SimdMinMax), 1);
        assert_eq!(b.mix.get(InstrClass::SimdStore), 1);
        assert_eq!(b.mix.bytes_read, 32);
        assert_eq!(b.mix.bytes_written, 16);
        assert_eq!(out[0], 0); // min(0, 16)
    }

    #[test]
    fn native_backend_computes_identically() {
        let data: Vec<u8> = (0..32).rev().collect();
        let mut n = Native;
        let mut c = Counting::new();
        let a1 = n.vld1q_u8(&data);
        let a2 = c.vld1q_u8(&data);
        assert_eq!(a1, a2);
        let k1 = n.vdupq_n_u8(20);
        let m1 = n.vmaxq_u8(a1, k1);
        let k2 = c.vdupq_n_u8(20);
        let m2 = c.vmaxq_u8(a2, k2);
        assert_eq!(m1, m2);
    }

    #[test]
    fn reinterpret_counted_as_free_class() {
        let mut b = Counting::new();
        let v = b.vdupq_n_u8(1);
        let _ = b.reinterpret_u16_u8(v);
        assert_eq!(b.mix.get(InstrClass::SimdReinterpret), 1);
        assert_eq!(b.mix.total_costed(), 1); // only the vdup
    }

    #[test]
    fn scalar_helpers_account() {
        let mut b = Counting::new();
        let src = vec![5u8, 9];
        let mut dst = vec![0u8; 2];
        let x = b.scalar_load_u8(&src, 0);
        let y = b.scalar_load_u8(&src, 1);
        let m = b.scalar_min_u8(x, y);
        b.scalar_store_u8(&mut dst, 0, m);
        b.scalar_overhead(3);
        assert_eq!(dst[0], 5);
        assert_eq!(b.mix.get(InstrClass::ScalarLoad), 2);
        assert_eq!(b.mix.get(InstrClass::ScalarCmp), 1);
        assert_eq!(b.mix.get(InstrClass::ScalarStore), 1);
        assert_eq!(b.mix.get(InstrClass::ScalarAlu), 3);
    }
}
