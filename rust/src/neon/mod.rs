//! ARM NEON simulator — the substituted hardware substrate.
//!
//! The paper's measurements were taken on a Samsung Exynos 5422 with the
//! NEON SIMD extension; this environment has neither.  Per the
//! substitution policy (DESIGN.md §Substitutions) we build the closest
//! synthetic equivalent that exercises the same code paths:
//!
//! * [`regs`] — 128-bit Q-register / 64-bit D-register value types
//!   (`U8x16`, `U16x8`, `U32x4`, `U32x2`, …) with the exact semantics of
//!   the instruction subset the paper uses (`vld1q`/`vst1q`, `vminq`/
//!   `vmaxq`, `vtrnq`, `vcombine`, `vget_low/high`, `vdupq`,
//!   `vreinterpretq`).
//! * [`counters`] — instruction-class accounting ([`InstrMix`]): every
//!   simulated instruction increments its class, giving the *instruction
//!   mix* of a pass.  The paper's efficiency claims are properties of
//!   this mix (counts of load/store, min/max, permute per pixel) times
//!   per-class cost; [`crate::costmodel`] prices a mix in Exynos-like
//!   nanoseconds.
//! * [`backend`] — the [`Backend`] trait: each intrinsic is a default
//!   method that computes via [`regs`] and records via
//!   [`Backend::record`].  Two implementations:
//!   [`Native`] (recording is a no-op that compiles away — algorithms run
//!   at full host speed for wall-clock benches) and [`Counting`]
//!   (accumulates an [`InstrMix`] for the cost model).  Every morphology
//!   and transpose algorithm in this crate is written once, generic over
//!   `Backend`, so the counted stream and the executed stream can never
//!   drift apart.

pub mod backend;
pub mod counters;
pub mod regs;

pub use backend::{Backend, Counting, Native};
pub use counters::{InstrClass, InstrMix};
pub use regs::{U16x4, U16x8, U32x2, U32x4, U64x2, U8x16, U8x8};
