//! NEON register value types and pure instruction semantics.
//!
//! Q registers are 128-bit (`U8x16`, `U16x8`, `U32x4`, `U64x2`), D
//! registers are their 64-bit halves (`U8x8`, `U16x4`, `U32x2`).  The
//! free functions implement the exact architectural semantics of each
//! instruction; accounting lives in [`super::backend`].
//!
//! Lane order follows the ARM little-endian convention: lane 0 is the
//! lowest-addressed element of a `vld1q` load.

/// 128-bit Q register viewed as 16 × u8.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct U8x16(pub [u8; 16]);

/// 128-bit Q register viewed as 8 × u16.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct U16x8(pub [u16; 8]);

/// 128-bit Q register viewed as 4 × u32.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct U32x4(pub [u32; 4]);

/// 128-bit Q register viewed as 2 × u64.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct U64x2(pub [u64; 2]);

/// 64-bit D register viewed as 8 × u8.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct U8x8(pub [u8; 8]);

/// 64-bit D register viewed as 4 × u16.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct U16x4(pub [u16; 4]);

/// 64-bit D register viewed as 2 × u32.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct U32x2(pub [u32; 2]);

// ---------------------------------------------------------------------------
// byte-level views (vreinterpretq semantics: pure bit reinterpretation)
// ---------------------------------------------------------------------------

macro_rules! q_bytes {
    ($ty:ty, $n:expr, $elem:ty) => {
        impl $ty {
            /// Little-endian byte image of the register.
            #[inline(always)]
            pub fn to_bytes(self) -> [u8; 16] {
                let mut out = [0u8; 16];
                for (i, v) in self.0.iter().enumerate() {
                    let b = v.to_le_bytes();
                    out[i * (16 / $n)..(i + 1) * (16 / $n)].copy_from_slice(&b);
                }
                out
            }

            /// Build from a little-endian byte image.
            #[inline(always)]
            pub fn from_bytes(bytes: [u8; 16]) -> Self {
                let mut lanes = [0 as $elem; $n];
                const W: usize = 16 / $n;
                for (i, lane) in lanes.iter_mut().enumerate() {
                    let mut b = [0u8; W];
                    b.copy_from_slice(&bytes[i * W..(i + 1) * W]);
                    *lane = <$elem>::from_le_bytes(b);
                }
                Self(lanes)
            }
        }
    };
}

q_bytes!(U8x16, 16, u8);
q_bytes!(U16x8, 8, u16);
q_bytes!(U32x4, 4, u32);
q_bytes!(U64x2, 2, u64);

// ---------------------------------------------------------------------------
// loads / stores
// ---------------------------------------------------------------------------

/// `VLD1.8 {q}, [r]` — load 16 consecutive u8.
#[inline(always)]
pub fn vld1q_u8(src: &[u8]) -> U8x16 {
    let mut v = [0u8; 16];
    v.copy_from_slice(&src[..16]);
    U8x16(v)
}

/// `VST1.8 {q}, [r]` — store 16 consecutive u8.
#[inline(always)]
pub fn vst1q_u8(dst: &mut [u8], v: U8x16) {
    dst[..16].copy_from_slice(&v.0);
}

/// `VLD1.16 {q}, [r]` — load 8 consecutive u16.
#[inline(always)]
pub fn vld1q_u16(src: &[u16]) -> U16x8 {
    let mut v = [0u16; 8];
    v.copy_from_slice(&src[..8]);
    U16x8(v)
}

/// `VST1.16 {q}, [r]` — store 8 consecutive u16.
#[inline(always)]
pub fn vst1q_u16(dst: &mut [u16], v: U16x8) {
    dst[..8].copy_from_slice(&v.0);
}

/// `VDUP.8 q, r` — broadcast a scalar to all 16 lanes.
#[inline(always)]
pub fn vdupq_n_u8(v: u8) -> U8x16 {
    U8x16([v; 16])
}

// ---------------------------------------------------------------------------
// min / max
// ---------------------------------------------------------------------------

/// `VMIN.U8 q, q, q` — lane-wise minimum of 16 u8 pairs.
///
/// On real aarch64 silicon this (and the other min/max semantics below)
/// lowers to the actual NEON intrinsic; everywhere else a scalar lane
/// loop carries the identical architectural semantics (the two paths
/// can never diverge — both are the lane-wise unsigned min).  The
/// aarch64 path is compile-checked in CI with a cross `cargo check
/// --target aarch64-unknown-linux-gnu` so it cannot silently rot on
/// x86 runners.
#[inline(always)]
pub fn vminq_u8(a: U8x16, b: U8x16) -> U8x16 {
    #[cfg(target_arch = "aarch64")]
    // SAFETY: NEON (asimd) is a mandatory feature of aarch64; the
    // pointers cover exactly 16 lanes of owned array storage.
    unsafe {
        use core::arch::aarch64 as neon;
        let r = neon::vminq_u8(neon::vld1q_u8(a.0.as_ptr()), neon::vld1q_u8(b.0.as_ptr()));
        let mut out = [0u8; 16];
        neon::vst1q_u8(out.as_mut_ptr(), r);
        U8x16(out)
    }
    #[cfg(not(target_arch = "aarch64"))]
    {
        let mut out = [0u8; 16];
        for i in 0..16 {
            out[i] = a.0[i].min(b.0[i]);
        }
        U8x16(out)
    }
}

/// `VMAX.U8 q, q, q` — lane-wise maximum of 16 u8 pairs.
#[inline(always)]
pub fn vmaxq_u8(a: U8x16, b: U8x16) -> U8x16 {
    #[cfg(target_arch = "aarch64")]
    // SAFETY: see `vminq_u8`.
    unsafe {
        use core::arch::aarch64 as neon;
        let r = neon::vmaxq_u8(neon::vld1q_u8(a.0.as_ptr()), neon::vld1q_u8(b.0.as_ptr()));
        let mut out = [0u8; 16];
        neon::vst1q_u8(out.as_mut_ptr(), r);
        U8x16(out)
    }
    #[cfg(not(target_arch = "aarch64"))]
    {
        let mut out = [0u8; 16];
        for i in 0..16 {
            out[i] = a.0[i].max(b.0[i]);
        }
        U8x16(out)
    }
}

/// `VMIN.U16` — lane-wise minimum of 8 u16 pairs.
#[inline(always)]
pub fn vminq_u16(a: U16x8, b: U16x8) -> U16x8 {
    #[cfg(target_arch = "aarch64")]
    // SAFETY: see `vminq_u8`.
    unsafe {
        use core::arch::aarch64 as neon;
        let r = neon::vminq_u16(neon::vld1q_u16(a.0.as_ptr()), neon::vld1q_u16(b.0.as_ptr()));
        let mut out = [0u16; 8];
        neon::vst1q_u16(out.as_mut_ptr(), r);
        U16x8(out)
    }
    #[cfg(not(target_arch = "aarch64"))]
    {
        let mut out = [0u16; 8];
        for i in 0..8 {
            out[i] = a.0[i].min(b.0[i]);
        }
        U16x8(out)
    }
}

/// `VMAX.U16` — lane-wise maximum of 8 u16 pairs.
#[inline(always)]
pub fn vmaxq_u16(a: U16x8, b: U16x8) -> U16x8 {
    #[cfg(target_arch = "aarch64")]
    // SAFETY: see `vminq_u8`.
    unsafe {
        use core::arch::aarch64 as neon;
        let r = neon::vmaxq_u16(neon::vld1q_u16(a.0.as_ptr()), neon::vld1q_u16(b.0.as_ptr()));
        let mut out = [0u16; 8];
        neon::vst1q_u16(out.as_mut_ptr(), r);
        U16x8(out)
    }
    #[cfg(not(target_arch = "aarch64"))]
    {
        let mut out = [0u16; 8];
        for i in 0..8 {
            out[i] = a.0[i].max(b.0[i]);
        }
        U16x8(out)
    }
}

// ---------------------------------------------------------------------------
// permutations: vtrn / vcombine / vget (the §4 transpose building blocks)
// ---------------------------------------------------------------------------

/// `VTRN.8 q, q` — treat the pair as 2×2 matrices of u8 and transpose
/// each: even lanes of `b` swap with odd lanes of `a` (paper Fig. 2).
#[inline(always)]
pub fn vtrnq_u8(a: U8x16, b: U8x16) -> (U8x16, U8x16) {
    let mut x = a.0;
    let mut y = b.0;
    for i in (0..16).step_by(2) {
        let t = x[i + 1];
        x[i + 1] = y[i];
        y[i] = t;
    }
    (U8x16(x), U8x16(y))
}

/// `VTRN.16 q, q` — 2×2 transpose of u16 element pairs.
#[inline(always)]
pub fn vtrnq_u16(a: U16x8, b: U16x8) -> (U16x8, U16x8) {
    let mut x = a.0;
    let mut y = b.0;
    for i in (0..8).step_by(2) {
        let t = x[i + 1];
        x[i + 1] = y[i];
        y[i] = t;
    }
    (U16x8(x), U16x8(y))
}

/// `VTRN.32 q, q` — 2×2 transpose of u32 element pairs.
#[inline(always)]
pub fn vtrnq_u32(a: U32x4, b: U32x4) -> (U32x4, U32x4) {
    let mut x = a.0;
    let mut y = b.0;
    for i in (0..4).step_by(2) {
        let t = x[i + 1];
        x[i + 1] = y[i];
        y[i] = t;
    }
    (U32x4(x), U32x4(y))
}

/// `VGET_LOW.32` — low D half of a Q register (register-allocation-level
/// on A32: free; counted separately so the cost model can zero it).
#[inline(always)]
pub fn vget_low_u32(a: U32x4) -> U32x2 {
    U32x2([a.0[0], a.0[1]])
}

/// `VGET_HIGH.32` — high D half of a Q register.
#[inline(always)]
pub fn vget_high_u32(a: U32x4) -> U32x2 {
    U32x2([a.0[2], a.0[3]])
}

/// `VCOMBINE.32` — join two D halves into one Q register.
#[inline(always)]
pub fn vcombine_u32(lo: U32x2, hi: U32x2) -> U32x4 {
    U32x4([lo.0[0], lo.0[1], hi.0[0], hi.0[1]])
}

/// `VSWP d, d`-style half swap expressed at Q level: returns
/// `(lo(a) ++ lo(b), hi(a) ++ hi(b))` — the 64-bit-block transpose step
/// used by the 16×16 network.
#[inline(always)]
pub fn vtrnq_u64(a: U64x2, b: U64x2) -> (U64x2, U64x2) {
    (U64x2([a.0[0], b.0[0]]), U64x2([a.0[1], b.0[1]]))
}

// ---------------------------------------------------------------------------
// reinterprets (pure bit casts; "auxiliary instructions ... do not affect
// efficiency" — §4)
// ---------------------------------------------------------------------------

/// `vreinterpretq_u32_u16`
#[inline(always)]
pub fn reinterpret_u32_u16(v: U16x8) -> U32x4 {
    U32x4::from_bytes(v.to_bytes())
}

/// `vreinterpretq_u16_u32`
#[inline(always)]
pub fn reinterpret_u16_u32(v: U32x4) -> U16x8 {
    U16x8::from_bytes(v.to_bytes())
}

/// `vreinterpretq_u16_u8`
#[inline(always)]
pub fn reinterpret_u16_u8(v: U8x16) -> U16x8 {
    U16x8::from_bytes(v.to_bytes())
}

/// `vreinterpretq_u8_u16`
#[inline(always)]
pub fn reinterpret_u8_u16(v: U16x8) -> U8x16 {
    U8x16::from_bytes(v.to_bytes())
}

/// `vreinterpretq_u32_u8`
#[inline(always)]
pub fn reinterpret_u32_u8(v: U8x16) -> U32x4 {
    U32x4::from_bytes(v.to_bytes())
}

/// `vreinterpretq_u8_u32`
#[inline(always)]
pub fn reinterpret_u8_u32(v: U32x4) -> U8x16 {
    U8x16::from_bytes(v.to_bytes())
}

/// `vreinterpretq_u64_u32`
#[inline(always)]
pub fn reinterpret_u64_u32(v: U32x4) -> U64x2 {
    U64x2::from_bytes(v.to_bytes())
}

/// `vreinterpretq_u32_u64`
#[inline(always)]
pub fn reinterpret_u32_u64(v: U64x2) -> U32x4 {
    U32x4::from_bytes(v.to_bytes())
}

/// `vreinterpretq_u64_u8`
#[inline(always)]
pub fn reinterpret_u64_u8(v: U8x16) -> U64x2 {
    U64x2::from_bytes(v.to_bytes())
}

/// `vreinterpretq_u8_u64`
#[inline(always)]
pub fn reinterpret_u8_u64(v: U64x2) -> U8x16 {
    U8x16::from_bytes(v.to_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_store_round_trip() {
        let src: Vec<u8> = (0..32).collect();
        let v = vld1q_u8(&src[4..]);
        assert_eq!(v.0[0], 4);
        assert_eq!(v.0[15], 19);
        let mut dst = [0u8; 20];
        vst1q_u8(&mut dst[2..], v);
        assert_eq!(&dst[2..18], &src[4..20]);
    }

    #[test]
    fn min_max_lanewise() {
        let a = U8x16([0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15]);
        let b = vdupq_n_u8(7);
        assert_eq!(vminq_u8(a, b).0[..4], [0, 1, 2, 3]);
        assert_eq!(vminq_u8(a, b).0[12..], [7, 7, 7, 7]);
        assert_eq!(vmaxq_u8(a, b).0[..4], [7, 7, 7, 7]);
        assert_eq!(vmaxq_u8(a, b).0[15], 15);
    }

    #[test]
    fn vtrn16_matches_paper_fig2() {
        // Paper Fig. 2: VTRN.16 swaps odd lanes of a with even lanes of b.
        let a = U16x8([0, 1, 2, 3, 4, 5, 6, 7]);
        let b = U16x8([10, 11, 12, 13, 14, 15, 16, 17]);
        let (x, y) = vtrnq_u16(a, b);
        assert_eq!(x.0, [0, 10, 2, 12, 4, 14, 6, 16]);
        assert_eq!(y.0, [1, 11, 3, 13, 5, 15, 7, 17]);
    }

    #[test]
    fn vtrn_is_involution() {
        let a = U8x16([3; 16]);
        let mut b = U8x16([9; 16]);
        b.0[0] = 1;
        let (x, y) = vtrnq_u8(a, b);
        let (x2, y2) = vtrnq_u8(x, y);
        assert_eq!(x2, a);
        assert_eq!(y2, b);
    }

    #[test]
    fn combine_get_round_trip() {
        let q = U32x4([1, 2, 3, 4]);
        let lo = vget_low_u32(q);
        let hi = vget_high_u32(q);
        assert_eq!(lo.0, [1, 2]);
        assert_eq!(hi.0, [3, 4]);
        assert_eq!(vcombine_u32(lo, hi), q);
    }

    #[test]
    fn reinterpret_preserves_bytes() {
        let v = U8x16([0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15]);
        let as_u16 = reinterpret_u16_u8(v);
        // little-endian: lane 0 of u16 view is bytes (0, 1) -> 0x0100
        assert_eq!(as_u16.0[0], 0x0100);
        assert_eq!(reinterpret_u8_u16(as_u16), v);
        let as_u32 = reinterpret_u32_u8(v);
        assert_eq!(as_u32.0[0], 0x03020100);
        assert_eq!(reinterpret_u8_u32(as_u32), v);
        let as_u64 = reinterpret_u64_u8(v);
        assert_eq!(as_u64.0[0], 0x0706050403020100);
        assert_eq!(reinterpret_u8_u64(as_u64), v);
    }

    #[test]
    fn vtrn64_swaps_halves() {
        let a = U64x2([1, 2]);
        let b = U64x2([3, 4]);
        let (x, y) = vtrnq_u64(a, b);
        assert_eq!(x.0, [1, 3]);
        assert_eq!(y.0, [2, 4]);
    }
}
