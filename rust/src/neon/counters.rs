//! Instruction-class accounting.
//!
//! Each simulated instruction belongs to one [`InstrClass`]; an
//! [`InstrMix`] is the histogram of classes executed by a pass.  The mix
//! is what the paper's efficiency arguments are actually about (§4
//! counts "16 load/store instructions, 32 data permutation instructions
//! and 16 auxiliary instructions" for the 8×8.16 transpose) and is the
//! input of [`crate::costmodel`].

use std::fmt;
use std::ops::{Add, AddAssign};

/// Classes of (simulated) instructions.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(usize)]
pub enum InstrClass {
    /// `vld1q` — 128-bit vector load (16-byte aligned stream).
    SimdLoad,
    /// `vld1q` at an arbitrary offset — the paper's §5.2.2 vertical pass
    /// issues loads at `x - wing + j` which are not 16-byte aligned;
    /// Cortex-A15 charges extra for these ("passes work with memory
    /// asymmetrically", §5.3 — the reason w_x⁰ < w_y⁰).
    SimdLoadUnaligned,
    /// `vst1q` — 128-bit vector store.
    SimdStore,
    /// `vminq` / `vmaxq` — vector min/max.
    SimdMinMax,
    /// `vtrnq` / `vdupq` — vector permutation.
    SimdPermute,
    /// `vcombine` / `vget_low` / `vget_high` — register-half plumbing.
    SimdCombine,
    /// `vreinterpretq` — auxiliary casts; §4: "do not affect efficiency".
    SimdReinterpret,
    /// Scalar element load.
    ScalarLoad,
    /// Scalar element store.
    ScalarStore,
    /// Scalar compare / min / max.
    ScalarCmp,
    /// Scalar address/index arithmetic and loop overhead.
    ScalarAlu,
}

impl InstrClass {
    pub const ALL: [InstrClass; 11] = [
        InstrClass::SimdLoad,
        InstrClass::SimdLoadUnaligned,
        InstrClass::SimdStore,
        InstrClass::SimdMinMax,
        InstrClass::SimdPermute,
        InstrClass::SimdCombine,
        InstrClass::SimdReinterpret,
        InstrClass::ScalarLoad,
        InstrClass::ScalarStore,
        InstrClass::ScalarCmp,
        InstrClass::ScalarAlu,
    ];

    pub fn name(self) -> &'static str {
        match self {
            InstrClass::SimdLoad => "simd_load",
            InstrClass::SimdLoadUnaligned => "simd_load_u",
            InstrClass::SimdStore => "simd_store",
            InstrClass::SimdMinMax => "simd_minmax",
            InstrClass::SimdPermute => "simd_permute",
            InstrClass::SimdCombine => "simd_combine",
            InstrClass::SimdReinterpret => "simd_reinterpret",
            InstrClass::ScalarLoad => "scalar_load",
            InstrClass::ScalarStore => "scalar_store",
            InstrClass::ScalarCmp => "scalar_cmp",
            InstrClass::ScalarAlu => "scalar_alu",
        }
    }

    pub fn is_simd(self) -> bool {
        matches!(
            self,
            InstrClass::SimdLoad
                | InstrClass::SimdLoadUnaligned
                | InstrClass::SimdStore
                | InstrClass::SimdMinMax
                | InstrClass::SimdPermute
                | InstrClass::SimdCombine
                | InstrClass::SimdReinterpret
        )
    }
}

/// Histogram of executed instructions by class, plus bytes moved to and
/// from memory (for the cost model's bandwidth term).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct InstrMix {
    counts: [u64; 11],
    /// Bytes read from memory (vector + scalar loads), counting every
    /// access — mostly cache traffic.
    pub bytes_read: u64,
    /// Bytes written to memory (vector + scalar stores), every access.
    pub bytes_written: u64,
    /// Unique bytes streamed *from DRAM* (each input/temp buffer counted
    /// once per sweep over it) — reported by the algorithm via
    /// [`crate::neon::Backend::record_stream`]; drives the cost model's
    /// bandwidth term.
    pub stream_read: u64,
    /// Unique bytes streamed *to DRAM*.
    pub stream_written: u64,
}

impl InstrMix {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline(always)]
    pub fn bump(&mut self, class: InstrClass, n: u64) {
        self.counts[class as usize] += n;
    }

    pub fn get(&self, class: InstrClass) -> u64 {
        self.counts[class as usize]
    }

    /// Total instruction count, excluding free reinterprets.
    pub fn total_costed(&self) -> u64 {
        InstrClass::ALL
            .iter()
            .filter(|c| !matches!(c, InstrClass::SimdReinterpret))
            .map(|&c| self.get(c))
            .sum()
    }

    /// Total instruction count including reinterprets.
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    pub fn simd_total(&self) -> u64 {
        InstrClass::ALL
            .iter()
            .filter(|c| c.is_simd())
            .map(|&c| self.get(c))
            .sum()
    }

    pub fn scalar_total(&self) -> u64 {
        self.total() - self.simd_total()
    }

    pub fn bytes_total(&self) -> u64 {
        self.bytes_read + self.bytes_written
    }

    /// `self - other` clamped at zero per class — mix of a region when
    /// `other` is a snapshot taken at its start.
    pub fn since(&self, snapshot: &InstrMix) -> InstrMix {
        let mut out = InstrMix::default();
        for (i, slot) in out.counts.iter_mut().enumerate() {
            *slot = self.counts[i].saturating_sub(snapshot.counts[i]);
        }
        out.bytes_read = self.bytes_read.saturating_sub(snapshot.bytes_read);
        out.bytes_written = self.bytes_written.saturating_sub(snapshot.bytes_written);
        out.stream_read = self.stream_read.saturating_sub(snapshot.stream_read);
        out.stream_written = self.stream_written.saturating_sub(snapshot.stream_written);
        out
    }

    /// Total unique DRAM-streamed bytes.
    pub fn stream_total(&self) -> u64 {
        self.stream_read + self.stream_written
    }
}

impl Add for InstrMix {
    type Output = InstrMix;
    fn add(self, rhs: InstrMix) -> InstrMix {
        let mut out = self;
        out += rhs;
        out
    }
}

impl AddAssign for InstrMix {
    fn add_assign(&mut self, rhs: InstrMix) {
        for i in 0..self.counts.len() {
            self.counts[i] += rhs.counts[i];
        }
        self.bytes_read += rhs.bytes_read;
        self.bytes_written += rhs.bytes_written;
        self.stream_read += rhs.stream_read;
        self.stream_written += rhs.stream_written;
    }
}

impl fmt::Display for InstrMix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut first = true;
        for &c in &InstrClass::ALL {
            let n = self.get(c);
            if n > 0 {
                if !first {
                    write!(f, " ")?;
                }
                write!(f, "{}={}", c.name(), n)?;
                first = false;
            }
        }
        if self.bytes_total() > 0 {
            write!(f, " rd={}B wr={}B", self.bytes_read, self.bytes_written)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_and_get() {
        let mut m = InstrMix::new();
        m.bump(InstrClass::SimdLoad, 3);
        m.bump(InstrClass::SimdMinMax, 5);
        m.bump(InstrClass::SimdReinterpret, 7);
        assert_eq!(m.get(InstrClass::SimdLoad), 3);
        assert_eq!(m.total(), 15);
        assert_eq!(m.total_costed(), 8); // reinterprets excluded
        assert_eq!(m.simd_total(), 15);
        assert_eq!(m.scalar_total(), 0);
    }

    #[test]
    fn since_subtracts() {
        let mut m = InstrMix::new();
        m.bump(InstrClass::ScalarLoad, 10);
        m.bytes_read = 100;
        let snap = m;
        m.bump(InstrClass::ScalarLoad, 5);
        m.bump(InstrClass::ScalarStore, 2);
        m.bytes_read = 160;
        let d = m.since(&snap);
        assert_eq!(d.get(InstrClass::ScalarLoad), 5);
        assert_eq!(d.get(InstrClass::ScalarStore), 2);
        assert_eq!(d.bytes_read, 60);
    }

    #[test]
    fn sum_mixes() {
        let mut a = InstrMix::new();
        a.bump(InstrClass::SimdStore, 1);
        let mut b = InstrMix::new();
        b.bump(InstrClass::SimdStore, 2);
        b.bytes_written = 32;
        let c = a + b;
        assert_eq!(c.get(InstrClass::SimdStore), 3);
        assert_eq!(c.bytes_written, 32);
    }

    #[test]
    fn display_compact() {
        let mut m = InstrMix::new();
        m.bump(InstrClass::SimdLoad, 2);
        let s = format!("{m}");
        assert!(s.contains("simd_load=2"));
        assert!(!s.contains("scalar"));
    }
}
