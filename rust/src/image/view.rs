//! Borrowed, strided image views — the crate's canonical kernel
//! argument.
//!
//! An [`ImageView`] is `(data ptr, height, width, stride)` over a
//! borrowed pixel buffer; an [`ImageViewMut`] is the same over a
//! mutable borrow.  Every morphology pass and transpose driver takes
//! views, so kernels run equally on
//!
//! * a whole [`Image`] (`img.view()` / `img.view_mut()`, or the
//!   `&Image → ImageView` [`From`] adapter every pass accepts),
//! * a **sub-rectangle** of one ([`ImageView::sub_rect`] — the
//!   region-of-interest entry points `erode_roi` / `dilate_roi` are
//!   built on this), and
//! * a **row band** of one ([`ImageView::sub_rows`] /
//!   [`ImageViewMut::split_at_rows_mut`]) — which is what makes the
//!   band-sharded parallel executor zero-copy: band jobs read
//!   overlapping haloed `src` views and write disjoint `dst` views
//!   in place, with no staging slab and no core-row stitch.
//!
//! ## Ownership rules
//!
//! * `ImageView` is `Copy` and many may alias the same pixels —
//!   overlapping *reads* (rows-pass halos) are plain shared borrows.
//! * `ImageViewMut` is unique: the only ways to get two are
//!   [`ImageViewMut::split_at_rows_mut`] / [`ImageViewMut::split_rows_mut`]
//!   (disjoint **row bands** — non-overlapping buffer halves) and
//!   [`ImageViewMut::split_cols_mut`] (disjoint **column stripes** —
//!   the banded §4 tile transpose's write geometry).  Row-band halves
//!   occupy non-overlapping buffer extents; column stripes *interleave*
//!   in memory (stripe `i`'s row `y` is `[y·stride + cᵢ.start,
//!   y·stride + cᵢ.end)`), which no pair of `&mut [P]` slices can
//!   express — so `ImageViewMut` carries a raw pointer internally and
//!   materializes per-row slices on access.  Logical-cell disjointness
//!   is still structural: siblings' row slices never overlap, because
//!   either their buffer extents are disjoint (row bands) or their
//!   column ranges are (stripes).  See the `unsafe` safety arguments on
//!   the splitters.
//! * Views never own pixels; whatever they borrow (usually an
//!   [`Image`]) must outlive them — the raw pointer is tagged with the
//!   borrow's lifetime (`PhantomData<&'a mut [P]>`), so ordinary Rust
//!   lifetimes still apply.
//! * `row`/`row_mut`/`get` touch this view's logical cells only, so a
//!   band job may use them while siblings write *their* cells.
//!   [`ImageViewMut::as_view`] instead re-borrows the view's whole
//!   backing span (padding and, for a stripe, interleaved sibling
//!   columns included) — never call it while a sibling view is being
//!   written.

use super::{Image, Pixel};
use std::marker::PhantomData;

/// Minimum buffer length backing an `h × w` view with row `stride`:
/// `h - 1` full strides plus one final `width`-row (the final row's
/// padding need not exist).
#[inline]
fn required_len(height: usize, width: usize, stride: usize) -> usize {
    if height == 0 || width == 0 {
        0
    } else {
        (height - 1) * stride + width
    }
}

/// A shared `height × width` view with row `stride` over borrowed
/// pixels.  See the module docs for the ownership rules.
#[derive(Clone, Copy, Debug)]
pub struct ImageView<'a, P: Pixel = u8> {
    height: usize,
    width: usize,
    stride: usize,
    data: &'a [P],
}

impl<'a, P: Pixel> ImageView<'a, P> {
    /// View over a row-major buffer (`data.len()` must cover
    /// `(height-1)*stride + width`; `stride >= width`).
    pub fn from_slice(data: &'a [P], height: usize, width: usize, stride: usize) -> Self {
        assert!(stride >= width, "stride {stride} < width {width}");
        assert!(
            data.len() >= required_len(height, width, stride),
            "buffer of {} elements cannot back a {height}x{width} view at stride {stride}",
            data.len()
        );
        ImageView {
            height,
            width,
            stride,
            data,
        }
    }

    pub fn height(self) -> usize {
        self.height
    }

    pub fn width(self) -> usize {
        self.width
    }

    pub fn stride(self) -> usize {
        self.stride
    }

    /// Logical pixels (excludes padding).
    pub fn pixels(self) -> usize {
        self.height * self.width
    }

    pub fn is_empty(self) -> bool {
        self.height == 0 || self.width == 0
    }

    /// Row `y` as a slice of `width` elements (excludes padding).
    #[inline]
    pub fn row(self, y: usize) -> &'a [P] {
        &self.data[y * self.stride..y * self.stride + self.width]
    }

    /// Row `y` including its padding — `stride` elements, except for
    /// the final row of a sub-view whose padding lies outside the
    /// borrowed buffer (then it is clipped to what exists).
    #[inline]
    pub fn row_padded(self, y: usize) -> &'a [P] {
        let start = y * self.stride;
        &self.data[start..((y + 1) * self.stride).min(self.data.len())]
    }

    #[inline]
    pub fn get(self, y: usize, x: usize) -> P {
        debug_assert!(y < self.height && x < self.width);
        self.data[y * self.stride + x]
    }

    /// Sub-view of rows `rows.start..rows.end` (same width/stride) —
    /// how band jobs borrow their haloed input.
    pub fn sub_rows(self, rows: std::ops::Range<usize>) -> ImageView<'a, P> {
        assert!(
            rows.start <= rows.end && rows.end <= self.height,
            "sub_rows {rows:?} out of 0..{}",
            self.height
        );
        let h = rows.len();
        let data = if h == 0 || self.width == 0 {
            &self.data[0..0]
        } else {
            let start = rows.start * self.stride;
            &self.data[start..start + required_len(h, self.width, self.stride)]
        };
        ImageView {
            height: h,
            width: self.width,
            stride: self.stride,
            data,
        }
    }

    /// Sub-view of the `height × width` rectangle at `(y0, x0)` — the
    /// region-of-interest constructor.  The sub-view keeps the parent's
    /// stride, so no pixel is copied.
    pub fn sub_rect(self, y0: usize, x0: usize, height: usize, width: usize) -> ImageView<'a, P> {
        assert!(
            y0 + height <= self.height && x0 + width <= self.width,
            "sub_rect {height}x{width}@({y0},{x0}) exceeds {}x{}",
            self.height,
            self.width
        );
        let data = if height == 0 || width == 0 {
            &self.data[0..0]
        } else {
            let start = y0 * self.stride + x0;
            &self.data[start..start + required_len(height, width, self.stride)]
        };
        ImageView {
            height,
            width,
            stride: self.stride,
            data,
        }
    }

    /// Owned compact copy (`stride == width`) of the viewed pixels.
    pub fn to_image(self) -> Image<P> {
        if self.height == 0 || self.width == 0 {
            return Image::zeros(self.height, self.width);
        }
        if self.stride == self.width {
            return Image::from_vec(self.height, self.width, self.data[..self.pixels()].to_vec());
        }
        let mut data = Vec::with_capacity(self.pixels());
        for y in 0..self.height {
            data.extend_from_slice(self.row(y));
        }
        Image::from_vec(self.height, self.width, data)
    }

    /// Pointwise equality of the logical pixels (padding ignored).
    pub fn same_pixels(self, other: ImageView<'_, P>) -> bool {
        self.height == other.height
            && self.width == other.width
            // width-0 sub-views carry an empty buffer; don't index it
            && (self.width == 0 || (0..self.height).all(|y| self.row(y) == other.row(y)))
    }
}

/// `&Image → ImageView` — the thin adapter that lets every pass keep
/// accepting `&Image<P>` at call sites while the kernels themselves
/// only know about borrowed views.
impl<'a, P: Pixel> From<&'a Image<P>> for ImageView<'a, P> {
    fn from(img: &'a Image<P>) -> Self {
        img.view()
    }
}

/// A unique mutable `height × width` view with row `stride` over
/// borrowed pixels.  Produced by [`Image::view_mut`] and split into
/// disjoint row bands with [`ImageViewMut::split_at_rows_mut`] or
/// disjoint column stripes with [`ImageViewMut::split_cols_mut`].
///
/// Internally this is `(ptr, len)` plus the geometry, not a
/// `&'a mut [P]`: sibling **column stripes** of one destination
/// interleave in memory (stripe rows alternate), so no partition into
/// non-overlapping `&mut [P]` slices can describe them — overlapping
/// mutable slices would be immediate UB even if never written.  The raw
/// pointer carries the borrow's lifetime via `PhantomData<&'a mut [P]>`
/// and every accessor materializes exactly the row slice it touches, so
/// sibling views (row bands *or* column stripes) never manufacture
/// references to each other's cells.
#[derive(Debug)]
pub struct ImageViewMut<'a, P: Pixel = u8> {
    height: usize,
    width: usize,
    stride: usize,
    ptr: *mut P,
    /// Elements reachable from `ptr` — every accessor stays within
    /// `ptr..ptr+len`, and the constructor asserts `len` covers the
    /// `height × width @ stride` geometry.
    len: usize,
    _marker: PhantomData<&'a mut [P]>,
}

// SAFETY: an `ImageViewMut` is semantically a `&'a mut [P]` restricted
// to its view geometry; `P: Pixel` already requires `Send + Sync`, so
// moving the view to another thread (band jobs) or sharing `&self`
// accessors is exactly as thread-safe as the slice borrow it replaces.
unsafe impl<P: Pixel> Send for ImageViewMut<'_, P> {}
unsafe impl<P: Pixel> Sync for ImageViewMut<'_, P> {}

impl<'a, P: Pixel> ImageViewMut<'a, P> {
    /// Mutable view over a row-major buffer (same length contract as
    /// [`ImageView::from_slice`]).
    pub fn from_slice_mut(data: &'a mut [P], height: usize, width: usize, stride: usize) -> Self {
        assert!(stride >= width, "stride {stride} < width {width}");
        assert!(
            data.len() >= required_len(height, width, stride),
            "buffer of {} elements cannot back a {height}x{width} view at stride {stride}",
            data.len()
        );
        ImageViewMut {
            height,
            width,
            stride,
            ptr: data.as_mut_ptr(),
            len: data.len(),
            _marker: PhantomData,
        }
    }

    pub fn height(&self) -> usize {
        self.height
    }

    pub fn width(&self) -> usize {
        self.width
    }

    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Reborrow as a shorter-lived unique view — lets a caller hand the
    /// same destination to several `_into` kernels in sequence (each
    /// takes an `ImageViewMut` by value).
    pub fn reborrow(&mut self) -> ImageViewMut<'_, P> {
        ImageViewMut {
            height: self.height,
            width: self.width,
            stride: self.stride,
            ptr: self.ptr,
            len: self.len,
            _marker: PhantomData,
        }
    }

    /// Reborrow as a shared view (for reading what was just written).
    ///
    /// This re-borrows the view's **whole backing span** — for a column
    /// stripe that span interleaves sibling columns, so it must not be
    /// called while any sibling view is being written (row-band halves
    /// back disjoint spans and have no such caveat).
    pub fn as_view(&self) -> ImageView<'_, P> {
        ImageView {
            height: self.height,
            width: self.width,
            stride: self.stride,
            // SAFETY: `ptr..ptr+len` is the span this view uniquely
            // borrows (`&self` pins it); callers of `as_view` observe
            // the sibling caveat documented above.
            data: unsafe { std::slice::from_raw_parts(self.ptr, self.len) },
        }
    }

    #[inline]
    pub fn row(&self, y: usize) -> &[P] {
        assert!(y < self.height, "row {y} out of 0..{}", self.height);
        // SAFETY: y < height and the constructor asserted
        // (height-1)·stride + width <= len, so the row slice is in
        // bounds; it covers only this view's logical cells.
        unsafe { std::slice::from_raw_parts(self.ptr.add(y * self.stride), self.width) }
    }

    #[inline]
    pub fn row_mut(&mut self, y: usize) -> &mut [P] {
        assert!(y < self.height, "row {y} out of 0..{}", self.height);
        // SAFETY: in bounds as in `row`; `&mut self` makes the borrow
        // unique, and sibling views never cover these cells.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(y * self.stride), self.width) }
    }

    /// Copy `self.height()` rows out of `src` starting at its row `y0`
    /// (the `window == 1` identity path of the `_into` kernels).
    pub fn copy_rows_from(&mut self, src: ImageView<'_, P>, y0: usize) {
        debug_assert_eq!(self.width, src.width());
        for i in 0..self.height {
            self.row_mut(i).copy_from_slice(src.row(y0 + i));
        }
    }

    /// Split into two disjoint views: rows `0..y` and rows `y..height`.
    ///
    /// This is the primitive the band-parallel executor builds on: the
    /// two halves borrow non-overlapping halves of the underlying
    /// buffer (`slice::split_at_mut`), so handing them to concurrent
    /// band jobs is data-race-free by construction.
    pub fn split_at_rows_mut(self, y: usize) -> (ImageViewMut<'a, P>, ImageViewMut<'a, P>) {
        assert!(y <= self.height, "split row {y} > height {}", self.height);
        // a minimally-sized buffer may omit the final row's padding, so
        // the y == height split point is clamped to what exists
        let mid = (y * self.stride).min(self.len);
        // SAFETY: consuming `self` transfers its unique borrow of
        // `ptr..ptr+len`; the halves partition that span at `mid`
        // (disjoint extents, together covering it), and each half's
        // geometry fits its extent by the constructor invariant.
        (
            ImageViewMut {
                height: y,
                width: self.width,
                stride: self.stride,
                ptr: self.ptr,
                len: mid,
                _marker: PhantomData,
            },
            ImageViewMut {
                height: self.height - y,
                width: self.width,
                stride: self.stride,
                ptr: unsafe { self.ptr.add(mid) },
                len: self.len - mid,
                _marker: PhantomData,
            },
        )
    }

    /// Partition into per-band disjoint views following `plan`, which
    /// must tile `0..height` contiguously (the output of
    /// `parallel::split_bands`).
    pub fn split_rows_mut(self, plan: &[std::ops::Range<usize>]) -> Vec<ImageViewMut<'a, P>> {
        let mut out = Vec::with_capacity(plan.len());
        let mut rest = self;
        let mut consumed = 0usize;
        for band in plan {
            assert_eq!(band.start, consumed, "plan must tile contiguously");
            let (head, tail) = rest.split_at_rows_mut(band.len());
            out.push(head);
            rest = tail;
            consumed = band.end;
        }
        assert_eq!(rest.height, 0, "plan must cover every row");
        out
    }

    /// Partition into per-stripe disjoint **column** views following
    /// `plan`, which must tile `0..width` contiguously (the output of
    /// `parallel::split_bands` / `split_bands_aligned` over the width).
    ///
    /// This is the write geometry of the banded §4 tile transpose: a
    /// band of *source tile-rows* `[y0, y1)` lands in *destination
    /// columns* `[y0, y1)` across every destination row, i.e. a column
    /// stripe.  Stripe `i` keeps the parent's stride with its origin
    /// advanced by `cᵢ.start`, so its rows interleave with its
    /// siblings' in memory — expressible here precisely because the
    /// view is pointer-based (see the type docs).
    ///
    /// Handing the stripes to concurrent band jobs is race-free: stripe
    /// `i`'s row `y` is the cell range `[y·stride + cᵢ.start,
    /// y·stride + cᵢ.end)`, and the `cᵢ` are pairwise disjoint, so no
    /// two stripes ever touch one cell (padding columns `width..stride`
    /// belong to no stripe and stay untouched).
    pub fn split_cols_mut(self, plan: &[std::ops::Range<usize>]) -> Vec<ImageViewMut<'a, P>> {
        let mut out = Vec::with_capacity(plan.len());
        let mut consumed = 0usize;
        for cols in plan {
            assert_eq!(cols.start, consumed, "plan must tile contiguously");
            assert!(!cols.is_empty(), "column stripes must be non-empty");
            // a height-0 view may back an empty buffer; clamp the
            // origin so the offset stays inside the borrowed span
            let off = cols.start.min(self.len);
            out.push(ImageViewMut {
                height: self.height,
                width: cols.len(),
                stride: self.stride,
                // SAFETY: `off <= len` keeps the advanced origin inside
                // (or one past) the borrowed span, and the stripe's
                // geometry fits its remaining span whenever height > 0:
                // (h-1)·stride + cols.end <= (h-1)·stride + width <= len.
                ptr: unsafe { self.ptr.add(off) },
                len: self.len - off,
                _marker: PhantomData,
            });
            consumed = cols.end;
        }
        assert_eq!(consumed, self.width, "plan must cover every column");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn img() -> Image<u8> {
        Image::from_fn(6, 9, |y, x| (y * 16 + x) as u8)
    }

    #[test]
    fn view_mirrors_image_accessors() {
        let im = img();
        let v = im.view();
        assert_eq!((v.height(), v.width(), v.stride()), (6, 9, 9));
        assert_eq!(v.pixels(), 54);
        assert_eq!(v.row(3), im.row(3));
        assert_eq!(v.row_padded(2), im.row_padded(2));
        assert_eq!(v.get(5, 8), im.get(5, 8));
        assert!(v.to_image().same_pixels(&im));
    }

    #[test]
    fn view_of_padded_image_is_stride_correct() {
        let im = img().with_stride(16, 0xEE);
        let v = im.view();
        assert_eq!(v.stride(), 16);
        assert_eq!(v.row(4), img().row(4));
        assert_eq!(v.row_padded(0).len(), 16);
        assert!(v.to_image().same_pixels(&img()));
        assert!(v.same_pixels(img().view()));
    }

    #[test]
    fn sub_rows_and_sub_rect_share_pixels() {
        let im = img();
        let v = im.view();
        let band = v.sub_rows(2..5);
        assert_eq!((band.height(), band.width()), (3, 9));
        assert_eq!(band.row(0), im.row(2));
        let r = v.sub_rect(1, 3, 4, 5);
        assert_eq!((r.height(), r.width()), (4, 5));
        assert_eq!(r.get(0, 0), im.get(1, 3));
        assert_eq!(r.get(3, 4), im.get(4, 7));
        // sub-view of a sub-view composes
        let rr = r.sub_rect(1, 1, 2, 2);
        assert_eq!(rr.get(0, 0), im.get(2, 4));
        assert_eq!(rr.to_image().get(1, 1), im.get(3, 5));
    }

    #[test]
    fn empty_sub_views_are_fine() {
        let im = img();
        let v = im.view();
        assert!(v.sub_rows(3..3).is_empty());
        assert!(v.sub_rect(0, 0, 0, 4).is_empty());
        assert_eq!(v.sub_rect(2, 2, 0, 0).to_image().pixels(), 0);
    }

    #[test]
    #[should_panic(expected = "sub_rect")]
    fn sub_rect_out_of_bounds_panics() {
        let im = img();
        let _ = im.view().sub_rect(3, 3, 4, 9);
    }

    #[test]
    fn split_at_rows_mut_handles_minimal_buffers() {
        // regression: a buffer without the final row's padding must
        // still split at y == height (empty tail)
        let mut buf = vec![0u8; 2 * 10 + 4]; // h=3, w=4, stride=10
        let v = ImageViewMut::from_slice_mut(&mut buf, 3, 4, 10);
        let (head, tail) = v.split_at_rows_mut(3);
        assert_eq!((head.height(), tail.height()), (3, 0));
        let v = ImageViewMut::from_slice_mut(&mut buf, 3, 4, 10);
        let parts = v.split_rows_mut(&[0..1, 1..3]);
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[1].height(), 2);
    }

    #[test]
    fn split_at_rows_mut_partitions() {
        let mut im = Image::<u8>::zeros(6, 4);
        {
            let (mut top, mut bot) = im.view_mut().split_at_rows_mut(2);
            assert_eq!((top.height(), bot.height()), (2, 4));
            top.row_mut(1).fill(7);
            bot.row_mut(0).fill(9);
        }
        assert_eq!(im.row(1), &[7, 7, 7, 7]);
        assert_eq!(im.row(2), &[9, 9, 9, 9]);
        assert_eq!(im.row(0), &[0, 0, 0, 0]);
    }

    #[test]
    fn split_rows_mut_follows_plan() {
        let mut im = Image::<u8>::zeros(7, 3);
        {
            let views = im.view_mut().split_rows_mut(&[0..2, 2..3, 3..7]);
            assert_eq!(views.len(), 3);
            for (i, mut v) in views.into_iter().enumerate() {
                for y in 0..v.height() {
                    v.row_mut(y).fill(i as u8 + 1);
                }
            }
        }
        assert_eq!(im.row(0)[0], 1);
        assert_eq!(im.row(2)[0], 2);
        assert_eq!(im.row(6)[0], 3);
    }

    #[test]
    fn split_cols_mut_partitions_columns() {
        let mut im = Image::<u8>::zeros(4, 6);
        {
            let stripes = im.view_mut().split_cols_mut(&[0..2, 2..3, 3..6]);
            assert_eq!(stripes.len(), 3);
            for (i, mut s) in stripes.into_iter().enumerate() {
                assert_eq!(s.height(), 4);
                for y in 0..s.height() {
                    s.row_mut(y).fill(i as u8 + 1);
                }
            }
        }
        assert_eq!(im.row(0), &[1, 1, 2, 3, 3, 3]);
        assert_eq!(im.row(3), &[1, 1, 2, 3, 3, 3]);
    }

    #[test]
    fn split_cols_mut_on_padded_image_leaves_padding() {
        let mut im = Image::<u8>::zeros(3, 5).with_stride(8, 0xAA);
        {
            let stripes = im.view_mut().split_cols_mut(&[0..3, 3..5]);
            for (i, mut s) in stripes.into_iter().enumerate() {
                for y in 0..s.height() {
                    s.row_mut(y).fill(i as u8 + 1);
                }
            }
        }
        assert_eq!(im.row(1), &[1, 1, 1, 2, 2]);
        assert_eq!(im.row_padded(1)[5], 0xAA, "padding untouched");
    }

    #[test]
    fn split_cols_mut_handles_minimal_buffers() {
        // final row's padding absent: the last stripe's rows must stay
        // inside the buffer
        let mut buf = vec![0u8; 2 * 10 + 4]; // h=3, w=4, stride=10
        let v = ImageViewMut::from_slice_mut(&mut buf, 3, 4, 10);
        let stripes = v.split_cols_mut(&[0..2, 2..4]);
        assert_eq!(stripes.len(), 2);
        let mut last = stripes.into_iter().nth(1).unwrap();
        last.row_mut(2).fill(9);
        assert_eq!(&buf[22..24], &[9, 9]);
    }

    #[test]
    #[should_panic(expected = "cover every column")]
    fn split_cols_mut_rejects_partial_plans() {
        let mut im = Image::<u8>::zeros(2, 6);
        let _ = im.view_mut().split_cols_mut(&[0..2, 2..5]);
    }

    #[test]
    fn mut_view_on_padded_image_writes_logical_rows_only() {
        let mut im = Image::<u8>::zeros(3, 5).with_stride(8, 0xAA);
        {
            let mut v = im.view_mut();
            v.row_mut(1).fill(3);
        }
        assert_eq!(im.row(1), &[3, 3, 3, 3, 3]);
        assert_eq!(im.row_padded(1)[5], 0xAA, "padding untouched");
    }

    #[test]
    fn copy_rows_from_with_offset() {
        let src = img();
        let mut dst = Image::<u8>::zeros(2, 9);
        dst.view_mut().copy_rows_from(src.view(), 3);
        assert_eq!(dst.row(0), src.row(3));
        assert_eq!(dst.row(1), src.row(4));
    }
}
