//! Borrowed, strided image views — the crate's canonical kernel
//! argument.
//!
//! An [`ImageView`] is `(data ptr, height, width, stride)` over a
//! borrowed pixel buffer; an [`ImageViewMut`] is the same over a
//! mutable borrow.  Every morphology pass and transpose driver takes
//! views, so kernels run equally on
//!
//! * a whole [`Image`] (`img.view()` / `img.view_mut()`, or the
//!   `&Image → ImageView` [`From`] adapter every pass accepts),
//! * a **sub-rectangle** of one ([`ImageView::sub_rect`] — the
//!   region-of-interest entry points `erode_roi` / `dilate_roi` are
//!   built on this), and
//! * a **row band** of one ([`ImageView::sub_rows`] /
//!   [`ImageViewMut::split_at_rows_mut`]) — which is what makes the
//!   band-sharded parallel executor zero-copy: band jobs read
//!   overlapping haloed `src` views and write disjoint `dst` views
//!   in place, with no staging slab and no core-row stitch.
//!
//! ## Ownership rules
//!
//! * `ImageView` is `Copy` and many may alias the same pixels —
//!   overlapping *reads* (rows-pass halos) are plain shared borrows.
//! * `ImageViewMut` is unique: the only way to get two is
//!   [`ImageViewMut::split_at_rows_mut`] (or [`ImageViewMut::split_rows_mut`]),
//!   which partitions the underlying `&mut [P]` with
//!   `slice::split_at_mut`, so disjointness of concurrent band writes
//!   is enforced by the borrow checker, not by convention.
//! * Views never own pixels; whatever they borrow (usually an
//!   [`Image`]) must outlive them — ordinary Rust lifetimes, no
//!   `unsafe` in this module.

use super::{Image, Pixel};

/// Minimum buffer length backing an `h × w` view with row `stride`:
/// `h - 1` full strides plus one final `width`-row (the final row's
/// padding need not exist).
#[inline]
fn required_len(height: usize, width: usize, stride: usize) -> usize {
    if height == 0 || width == 0 {
        0
    } else {
        (height - 1) * stride + width
    }
}

/// A shared `height × width` view with row `stride` over borrowed
/// pixels.  See the module docs for the ownership rules.
#[derive(Clone, Copy, Debug)]
pub struct ImageView<'a, P: Pixel = u8> {
    height: usize,
    width: usize,
    stride: usize,
    data: &'a [P],
}

impl<'a, P: Pixel> ImageView<'a, P> {
    /// View over a row-major buffer (`data.len()` must cover
    /// `(height-1)*stride + width`; `stride >= width`).
    pub fn from_slice(data: &'a [P], height: usize, width: usize, stride: usize) -> Self {
        assert!(stride >= width, "stride {stride} < width {width}");
        assert!(
            data.len() >= required_len(height, width, stride),
            "buffer of {} elements cannot back a {height}x{width} view at stride {stride}",
            data.len()
        );
        ImageView {
            height,
            width,
            stride,
            data,
        }
    }

    pub fn height(self) -> usize {
        self.height
    }

    pub fn width(self) -> usize {
        self.width
    }

    pub fn stride(self) -> usize {
        self.stride
    }

    /// Logical pixels (excludes padding).
    pub fn pixels(self) -> usize {
        self.height * self.width
    }

    pub fn is_empty(self) -> bool {
        self.height == 0 || self.width == 0
    }

    /// Row `y` as a slice of `width` elements (excludes padding).
    #[inline]
    pub fn row(self, y: usize) -> &'a [P] {
        &self.data[y * self.stride..y * self.stride + self.width]
    }

    /// Row `y` including its padding — `stride` elements, except for
    /// the final row of a sub-view whose padding lies outside the
    /// borrowed buffer (then it is clipped to what exists).
    #[inline]
    pub fn row_padded(self, y: usize) -> &'a [P] {
        let start = y * self.stride;
        &self.data[start..((y + 1) * self.stride).min(self.data.len())]
    }

    #[inline]
    pub fn get(self, y: usize, x: usize) -> P {
        debug_assert!(y < self.height && x < self.width);
        self.data[y * self.stride + x]
    }

    /// Sub-view of rows `rows.start..rows.end` (same width/stride) —
    /// how band jobs borrow their haloed input.
    pub fn sub_rows(self, rows: std::ops::Range<usize>) -> ImageView<'a, P> {
        assert!(
            rows.start <= rows.end && rows.end <= self.height,
            "sub_rows {rows:?} out of 0..{}",
            self.height
        );
        let h = rows.len();
        let data = if h == 0 || self.width == 0 {
            &self.data[0..0]
        } else {
            let start = rows.start * self.stride;
            &self.data[start..start + required_len(h, self.width, self.stride)]
        };
        ImageView {
            height: h,
            width: self.width,
            stride: self.stride,
            data,
        }
    }

    /// Sub-view of the `height × width` rectangle at `(y0, x0)` — the
    /// region-of-interest constructor.  The sub-view keeps the parent's
    /// stride, so no pixel is copied.
    pub fn sub_rect(self, y0: usize, x0: usize, height: usize, width: usize) -> ImageView<'a, P> {
        assert!(
            y0 + height <= self.height && x0 + width <= self.width,
            "sub_rect {height}x{width}@({y0},{x0}) exceeds {}x{}",
            self.height,
            self.width
        );
        let data = if height == 0 || width == 0 {
            &self.data[0..0]
        } else {
            let start = y0 * self.stride + x0;
            &self.data[start..start + required_len(height, width, self.stride)]
        };
        ImageView {
            height,
            width,
            stride: self.stride,
            data,
        }
    }

    /// Owned compact copy (`stride == width`) of the viewed pixels.
    pub fn to_image(self) -> Image<P> {
        if self.height == 0 || self.width == 0 {
            return Image::zeros(self.height, self.width);
        }
        if self.stride == self.width {
            return Image::from_vec(self.height, self.width, self.data[..self.pixels()].to_vec());
        }
        let mut data = Vec::with_capacity(self.pixels());
        for y in 0..self.height {
            data.extend_from_slice(self.row(y));
        }
        Image::from_vec(self.height, self.width, data)
    }

    /// Pointwise equality of the logical pixels (padding ignored).
    pub fn same_pixels(self, other: ImageView<'_, P>) -> bool {
        self.height == other.height
            && self.width == other.width
            // width-0 sub-views carry an empty buffer; don't index it
            && (self.width == 0 || (0..self.height).all(|y| self.row(y) == other.row(y)))
    }
}

/// `&Image → ImageView` — the thin adapter that lets every pass keep
/// accepting `&Image<P>` at call sites while the kernels themselves
/// only know about borrowed views.
impl<'a, P: Pixel> From<&'a Image<P>> for ImageView<'a, P> {
    fn from(img: &'a Image<P>) -> Self {
        img.view()
    }
}

/// A unique mutable `height × width` view with row `stride` over
/// borrowed pixels.  Produced by [`Image::view_mut`] and split into
/// disjoint row bands with [`ImageViewMut::split_at_rows_mut`].
#[derive(Debug)]
pub struct ImageViewMut<'a, P: Pixel = u8> {
    height: usize,
    width: usize,
    stride: usize,
    data: &'a mut [P],
}

impl<'a, P: Pixel> ImageViewMut<'a, P> {
    /// Mutable view over a row-major buffer (same length contract as
    /// [`ImageView::from_slice`]).
    pub fn from_slice_mut(data: &'a mut [P], height: usize, width: usize, stride: usize) -> Self {
        assert!(stride >= width, "stride {stride} < width {width}");
        assert!(
            data.len() >= required_len(height, width, stride),
            "buffer of {} elements cannot back a {height}x{width} view at stride {stride}",
            data.len()
        );
        ImageViewMut {
            height,
            width,
            stride,
            data,
        }
    }

    pub fn height(&self) -> usize {
        self.height
    }

    pub fn width(&self) -> usize {
        self.width
    }

    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Reborrow as a shorter-lived unique view — lets a caller hand the
    /// same destination to several `_into` kernels in sequence (each
    /// takes an `ImageViewMut` by value).
    pub fn reborrow(&mut self) -> ImageViewMut<'_, P> {
        ImageViewMut {
            height: self.height,
            width: self.width,
            stride: self.stride,
            data: &mut *self.data,
        }
    }

    /// Reborrow as a shared view (for reading what was just written).
    pub fn as_view(&self) -> ImageView<'_, P> {
        ImageView {
            height: self.height,
            width: self.width,
            stride: self.stride,
            data: self.data,
        }
    }

    #[inline]
    pub fn row(&self, y: usize) -> &[P] {
        &self.data[y * self.stride..y * self.stride + self.width]
    }

    #[inline]
    pub fn row_mut(&mut self, y: usize) -> &mut [P] {
        &mut self.data[y * self.stride..y * self.stride + self.width]
    }

    /// Copy `self.height()` rows out of `src` starting at its row `y0`
    /// (the `window == 1` identity path of the `_into` kernels).
    pub fn copy_rows_from(&mut self, src: ImageView<'_, P>, y0: usize) {
        debug_assert_eq!(self.width, src.width());
        for i in 0..self.height {
            self.row_mut(i).copy_from_slice(src.row(y0 + i));
        }
    }

    /// Split into two disjoint views: rows `0..y` and rows `y..height`.
    ///
    /// This is the primitive the band-parallel executor builds on: the
    /// two halves borrow non-overlapping halves of the underlying
    /// buffer (`slice::split_at_mut`), so handing them to concurrent
    /// band jobs is data-race-free by construction.
    pub fn split_at_rows_mut(self, y: usize) -> (ImageViewMut<'a, P>, ImageViewMut<'a, P>) {
        assert!(y <= self.height, "split row {y} > height {}", self.height);
        // a minimally-sized buffer may omit the final row's padding, so
        // the y == height split point is clamped to what exists
        let mid = (y * self.stride).min(self.data.len());
        let (head, tail) = self.data.split_at_mut(mid);
        (
            ImageViewMut {
                height: y,
                width: self.width,
                stride: self.stride,
                data: head,
            },
            ImageViewMut {
                height: self.height - y,
                width: self.width,
                stride: self.stride,
                data: tail,
            },
        )
    }

    /// Partition into per-band disjoint views following `plan`, which
    /// must tile `0..height` contiguously (the output of
    /// `parallel::split_bands`).
    pub fn split_rows_mut(self, plan: &[std::ops::Range<usize>]) -> Vec<ImageViewMut<'a, P>> {
        let mut out = Vec::with_capacity(plan.len());
        let mut rest = self;
        let mut consumed = 0usize;
        for band in plan {
            assert_eq!(band.start, consumed, "plan must tile contiguously");
            let (head, tail) = rest.split_at_rows_mut(band.len());
            out.push(head);
            rest = tail;
            consumed = band.end;
        }
        assert_eq!(rest.height, 0, "plan must cover every row");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn img() -> Image<u8> {
        Image::from_fn(6, 9, |y, x| (y * 16 + x) as u8)
    }

    #[test]
    fn view_mirrors_image_accessors() {
        let im = img();
        let v = im.view();
        assert_eq!((v.height(), v.width(), v.stride()), (6, 9, 9));
        assert_eq!(v.pixels(), 54);
        assert_eq!(v.row(3), im.row(3));
        assert_eq!(v.row_padded(2), im.row_padded(2));
        assert_eq!(v.get(5, 8), im.get(5, 8));
        assert!(v.to_image().same_pixels(&im));
    }

    #[test]
    fn view_of_padded_image_is_stride_correct() {
        let im = img().with_stride(16, 0xEE);
        let v = im.view();
        assert_eq!(v.stride(), 16);
        assert_eq!(v.row(4), img().row(4));
        assert_eq!(v.row_padded(0).len(), 16);
        assert!(v.to_image().same_pixels(&img()));
        assert!(v.same_pixels(img().view()));
    }

    #[test]
    fn sub_rows_and_sub_rect_share_pixels() {
        let im = img();
        let v = im.view();
        let band = v.sub_rows(2..5);
        assert_eq!((band.height(), band.width()), (3, 9));
        assert_eq!(band.row(0), im.row(2));
        let r = v.sub_rect(1, 3, 4, 5);
        assert_eq!((r.height(), r.width()), (4, 5));
        assert_eq!(r.get(0, 0), im.get(1, 3));
        assert_eq!(r.get(3, 4), im.get(4, 7));
        // sub-view of a sub-view composes
        let rr = r.sub_rect(1, 1, 2, 2);
        assert_eq!(rr.get(0, 0), im.get(2, 4));
        assert_eq!(rr.to_image().get(1, 1), im.get(3, 5));
    }

    #[test]
    fn empty_sub_views_are_fine() {
        let im = img();
        let v = im.view();
        assert!(v.sub_rows(3..3).is_empty());
        assert!(v.sub_rect(0, 0, 0, 4).is_empty());
        assert_eq!(v.sub_rect(2, 2, 0, 0).to_image().pixels(), 0);
    }

    #[test]
    #[should_panic(expected = "sub_rect")]
    fn sub_rect_out_of_bounds_panics() {
        let im = img();
        let _ = im.view().sub_rect(3, 3, 4, 9);
    }

    #[test]
    fn split_at_rows_mut_handles_minimal_buffers() {
        // regression: a buffer without the final row's padding must
        // still split at y == height (empty tail)
        let mut buf = vec![0u8; 2 * 10 + 4]; // h=3, w=4, stride=10
        let v = ImageViewMut::from_slice_mut(&mut buf, 3, 4, 10);
        let (head, tail) = v.split_at_rows_mut(3);
        assert_eq!((head.height(), tail.height()), (3, 0));
        let v = ImageViewMut::from_slice_mut(&mut buf, 3, 4, 10);
        let parts = v.split_rows_mut(&[0..1, 1..3]);
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[1].height(), 2);
    }

    #[test]
    fn split_at_rows_mut_partitions() {
        let mut im = Image::<u8>::zeros(6, 4);
        {
            let (mut top, mut bot) = im.view_mut().split_at_rows_mut(2);
            assert_eq!((top.height(), bot.height()), (2, 4));
            top.row_mut(1).fill(7);
            bot.row_mut(0).fill(9);
        }
        assert_eq!(im.row(1), &[7, 7, 7, 7]);
        assert_eq!(im.row(2), &[9, 9, 9, 9]);
        assert_eq!(im.row(0), &[0, 0, 0, 0]);
    }

    #[test]
    fn split_rows_mut_follows_plan() {
        let mut im = Image::<u8>::zeros(7, 3);
        {
            let views = im.view_mut().split_rows_mut(&[0..2, 2..3, 3..7]);
            assert_eq!(views.len(), 3);
            for (i, mut v) in views.into_iter().enumerate() {
                for y in 0..v.height() {
                    v.row_mut(y).fill(i as u8 + 1);
                }
            }
        }
        assert_eq!(im.row(0)[0], 1);
        assert_eq!(im.row(2)[0], 2);
        assert_eq!(im.row(6)[0], 3);
    }

    #[test]
    fn mut_view_on_padded_image_writes_logical_rows_only() {
        let mut im = Image::<u8>::zeros(3, 5).with_stride(8, 0xAA);
        {
            let mut v = im.view_mut();
            v.row_mut(1).fill(3);
        }
        assert_eq!(im.row(1), &[3, 3, 3, 3, 3]);
        assert_eq!(im.row_padded(1)[5], 0xAA, "padding untouched");
    }

    #[test]
    fn copy_rows_from_with_offset() {
        let src = img();
        let mut dst = Image::<u8>::zeros(2, 9);
        dst.view_mut().copy_rows_from(src.view(), 3);
        assert_eq!(dst.row(0), src.row(3));
        assert_eq!(dst.row(1), src.row(4));
    }
}
