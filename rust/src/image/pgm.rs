//! Minimal PGM (P5 binary / P2 ascii) reader and writer.
//!
//! The paper's workload is an 8-bit gray image; PGM is the simplest
//! interchange that real tools (ImageMagick, OpenCV, GIMP) all read, so
//! the examples can consume and emit actual files.

use std::fs;
use std::io::{self, Read, Write};
use std::path::Path;

use super::Image;

/// Write `img` as binary PGM (P5, maxval 255).
pub fn write_pgm(img: &Image<u8>, path: impl AsRef<Path>) -> io::Result<()> {
    let mut f = fs::File::create(path)?;
    write!(f, "P5\n{} {}\n255\n", img.width(), img.height())?;
    for y in 0..img.height() {
        f.write_all(img.row(y))?;
    }
    Ok(())
}

/// Read a PGM file (P5 binary or P2 ascii, maxval <= 255).
pub fn read_pgm(path: impl AsRef<Path>) -> io::Result<Image<u8>> {
    let mut bytes = Vec::new();
    fs::File::open(path)?.read_to_end(&mut bytes)?;
    parse_pgm(&bytes)
}

/// Parse PGM from a byte buffer.
pub fn parse_pgm(bytes: &[u8]) -> io::Result<Image<u8>> {
    let bad = |msg: &str| io::Error::new(io::ErrorKind::InvalidData, msg.to_string());
    let mut pos = 0usize;

    // token reader skipping whitespace and '#' comments
    let next_token = |pos: &mut usize| -> io::Result<String> {
        loop {
            while *pos < bytes.len() && bytes[*pos].is_ascii_whitespace() {
                *pos += 1;
            }
            if *pos < bytes.len() && bytes[*pos] == b'#' {
                while *pos < bytes.len() && bytes[*pos] != b'\n' {
                    *pos += 1;
                }
                continue;
            }
            break;
        }
        let start = *pos;
        while *pos < bytes.len() && !bytes[*pos].is_ascii_whitespace() {
            *pos += 1;
        }
        if start == *pos {
            return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "pgm: eof"));
        }
        Ok(String::from_utf8_lossy(&bytes[start..*pos]).into_owned())
    };

    let magic = next_token(&mut pos)?;
    if magic != "P5" && magic != "P2" {
        return Err(bad(&format!("pgm: unsupported magic {magic:?}")));
    }
    let width: usize = next_token(&mut pos)?.parse().map_err(|_| bad("pgm: bad width"))?;
    let height: usize = next_token(&mut pos)?.parse().map_err(|_| bad("pgm: bad height"))?;
    let maxval: usize = next_token(&mut pos)?.parse().map_err(|_| bad("pgm: bad maxval"))?;
    if maxval == 0 || maxval > 255 {
        return Err(bad(&format!("pgm: unsupported maxval {maxval}")));
    }

    let n = width * height;
    let data = if magic == "P5" {
        // single whitespace after maxval, then raw bytes
        pos += 1;
        if bytes.len() < pos + n {
            return Err(bad("pgm: truncated raster"));
        }
        bytes[pos..pos + n].to_vec()
    } else {
        let mut data = Vec::with_capacity(n);
        for _ in 0..n {
            let v: usize = next_token(&mut pos)?.parse().map_err(|_| bad("pgm: bad pixel"))?;
            if v > maxval {
                return Err(bad("pgm: pixel > maxval"));
            }
            data.push(v as u8);
        }
        data
    };
    Ok(Image::from_vec(height, width, data))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn p5_round_trip() {
        let img = Image::from_fn(13, 29, |y, x| (y * 31 + x * 7) as u8);
        let dir = std::env::temp_dir().join("neon_morph_pgm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.pgm");
        write_pgm(&img, &path).unwrap();
        let back = read_pgm(&path).unwrap();
        assert!(back.same_pixels(&img));
    }

    #[test]
    fn p2_ascii_with_comments() {
        let txt = b"P2\n# comment line\n3 2\n255\n0 1 2\n250 251 252\n";
        let img = parse_pgm(txt).unwrap();
        assert_eq!(img.height(), 2);
        assert_eq!(img.width(), 3);
        assert_eq!(img.get(0, 2), 2);
        assert_eq!(img.get(1, 0), 250);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_pgm(b"P6\n1 1\n255\nx").is_err());
        assert!(parse_pgm(b"P5\n4 4\n255\nxy").is_err()); // truncated
        assert!(parse_pgm(b"P2\n1 1\n70000\n0").is_err()); // 16-bit maxval
    }
}
