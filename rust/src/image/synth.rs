//! Synthetic workload generators.
//!
//! The paper benchmarks on a real 800×600 gray photograph.  Min/max
//! filters are data-independent in running time, so any image of the same
//! dimensions and dtype reproduces the timing behaviour; these generators
//! also produce *structured* content (document page, shapes) so the
//! examples demonstrate visually meaningful morphology, and noise images
//! so tests exercise arbitrary data.

use super::Image;

/// Paper workload dimensions: "gray image of width of 800 pixels and
/// height of 600 pixels with 8-bit unsigned integer data".
pub const PAPER_WIDTH: usize = 800;
pub const PAPER_HEIGHT: usize = 600;

/// Deterministic xorshift64* PRNG — no external deps, stable across
/// platforms so tests and benches are reproducible.
#[derive(Clone, Debug)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng(seed.max(1))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    #[inline]
    pub fn next_u8(&mut self) -> u8 {
        (self.next_u64() >> 56) as u8
    }

    #[inline]
    pub fn next_u16(&mut self) -> u16 {
        (self.next_u64() >> 48) as u16
    }

    /// Uniform in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }
}

/// Uniform random noise image — the default test/bench workload.
pub fn noise(height: usize, width: usize, seed: u64) -> Image<u8> {
    let mut rng = Rng::new(seed);
    Image::from_fn(height, width, |_, _| rng.next_u8())
}

/// The paper's workload shape filled with noise.
pub fn paper_image(seed: u64) -> Image<u8> {
    noise(PAPER_HEIGHT, PAPER_WIDTH, seed)
}

/// Uniform random 16-bit noise image — the u16 test/bench workload
/// (full 0..=65535 range, so u16-only values exercise the wide lanes).
pub fn noise_u16(height: usize, width: usize, seed: u64) -> Image<u16> {
    let mut rng = Rng::new(seed);
    Image::from_fn(height, width, |_, _| rng.next_u16())
}

/// The paper's workload shape at 16-bit depth (§4's 8×8.16 scenario).
pub fn paper_image_u16(seed: u64) -> Image<u16> {
    noise_u16(PAPER_HEIGHT, PAPER_WIDTH, seed)
}

/// Smooth diagonal gradient (useful for eyeballing pass direction bugs).
pub fn gradient(height: usize, width: usize) -> Image<u8> {
    Image::from_fn(height, width, |y, x| {
        let h = (height.max(2) - 1) as f64;
        let w = (width.max(2) - 1) as f64;
        (255.0 * (y as f64 / h + x as f64 / w) / 2.0) as u8
    })
}

/// Checkerboard with `cell`-pixel squares (black 0 / white 255).
pub fn checkerboard(height: usize, width: usize, cell: usize) -> Image<u8> {
    let cell = cell.max(1);
    Image::from_fn(height, width, |y, x| {
        if ((y / cell) + (x / cell)) % 2 == 0 {
            0
        } else {
            255
        }
    })
}

/// A document-like page: white background, dark horizontal "text line"
/// strokes with varying lengths plus salt noise — the recognition-system
/// workload the paper's introduction motivates (morphology is used there
/// to clean/extract text structure).
pub fn document(height: usize, width: usize, seed: u64) -> Image<u8> {
    let mut img = Image::filled(height, width, 245u8);
    let mut rng = Rng::new(seed);
    let line_height = 8usize;
    let line_gap = 6usize;
    let mut y = line_gap;
    while y + line_height < height {
        // words of random length separated by spaces
        let mut x = 4 + rng.below(12);
        while x + 6 < width {
            let word = 12 + rng.below(40);
            let end = (x + word).min(width - 2);
            for yy in y..y + line_height {
                for xx in x..end {
                    // glyph texture: mostly dark with internal variation
                    let v = 20 + (rng.next_u8() % 60);
                    img.set(yy, xx, v);
                }
            }
            x = end + 4 + rng.below(10);
        }
        y += line_height + line_gap;
    }
    // salt noise: isolated bright/dark specks that opening/closing remove
    for _ in 0..(height * width / 400) {
        let yy = rng.below(height);
        let xx = rng.below(width);
        img.set(yy, xx, if rng.next_u8() & 1 == 0 { 0 } else { 255 });
    }
    img
}

/// Sparse impulse image: identity background with `count` random spikes —
/// the adversarial case for running-min correctness (every spike must
/// propagate to exactly the window footprint).
pub fn impulses(height: usize, width: usize, count: usize, seed: u64) -> Image<u8> {
    let mut img = Image::filled(height, width, 128u8);
    let mut rng = Rng::new(seed);
    for _ in 0..count {
        let y = rng.below(height.max(1));
        let x = rng.below(width.max(1));
        img.set(y, x, if rng.next_u8() & 1 == 0 { 0 } else { 255 });
    }
    img
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noise_is_deterministic() {
        let a = noise(32, 32, 42);
        let b = noise(32, 32, 42);
        let c = noise(32, 32, 43);
        assert!(a.same_pixels(&b));
        assert!(!a.same_pixels(&c));
    }

    #[test]
    fn paper_image_dims() {
        let img = paper_image(1);
        assert_eq!(img.height(), 600);
        assert_eq!(img.width(), 800);
        let img16 = paper_image_u16(1);
        assert_eq!(img16.height(), 600);
        assert_eq!(img16.width(), 800);
    }

    #[test]
    fn u16_noise_uses_the_full_range() {
        let img = noise_u16(64, 64, 99);
        let (mn, mx) = img.min_max().unwrap();
        assert!(mx > u8::MAX as u16, "u16 noise must exceed the u8 range");
        assert!(mn < 1000, "u16 noise should reach low values too");
        assert!(noise_u16(8, 8, 5).same_pixels(&noise_u16(8, 8, 5)));
    }

    #[test]
    fn gradient_monotone_on_diagonal() {
        let g = gradient(64, 64);
        assert!(g.get(0, 0) <= g.get(32, 32));
        assert!(g.get(32, 32) <= g.get(63, 63));
    }

    #[test]
    fn checkerboard_alternates() {
        let c = checkerboard(8, 8, 2);
        assert_eq!(c.get(0, 0), 0);
        assert_eq!(c.get(0, 2), 255);
        assert_eq!(c.get(2, 0), 255);
        assert_eq!(c.get(2, 2), 0);
    }

    #[test]
    fn document_has_text_and_background() {
        let d = document(120, 200, 7);
        let (mn, mx) = d.min_max().unwrap();
        assert!(mn < 64, "expected dark strokes, min={mn}");
        assert!(mx > 200, "expected light background, max={mx}");
    }

    #[test]
    fn impulses_change_exactly_some_pixels() {
        let img = impulses(50, 50, 20, 3);
        let changed = (0..50)
            .flat_map(|y| (0..50).map(move |x| (y, x)))
            .filter(|&(y, x)| img.get(y, x) != 128)
            .count();
        assert!(changed > 0 && changed <= 20);
    }
}
