//! Image containers, borrowed views, I/O and synthetic workload
//! generation.
//!
//! The paper's experiments run on an 800×600 gray image with 8-bit
//! unsigned data; [`Image<u8>`] is the crate-wide *owning* pixel
//! container.  The container is stride-aware so row-aligned SIMD passes
//! can work on padded rows without copying, and every kernel in
//! [`crate::morphology`] / [`crate::transpose`] actually operates on
//! borrowed [`ImageView`] / [`ImageViewMut`] windows into it (see
//! [`view`]'s module docs for the ownership rules) — `&Image` converts
//! into a whole-image view implicitly at every pass entry point, while
//! sub-row and sub-rectangle views power the zero-copy band-parallel
//! executor and the region-of-interest API.

mod pgm;
pub mod synth;
pub mod view;

pub use pgm::{read_pgm, write_pgm};
pub use view::{ImageView, ImageViewMut};

/// Pixel element: the subset of integer types the paper's kernels use.
pub trait Pixel:
    Copy
    + Ord
    + Default
    + Send
    + Sync
    + std::fmt::Debug
    + std::fmt::Display
    + 'static
{
    /// Identity of `min` (all-ones) — erosion's padding value.
    const MAX_VALUE: Self;
    /// Identity of `max` (zero) — dilation's padding value.
    const MIN_VALUE: Self;
    fn from_u8(v: u8) -> Self;
    fn to_u64(self) -> u64;
}

impl Pixel for u8 {
    const MAX_VALUE: u8 = u8::MAX;
    const MIN_VALUE: u8 = u8::MIN;
    fn from_u8(v: u8) -> Self {
        v
    }
    fn to_u64(self) -> u64 {
        self as u64
    }
}

impl Pixel for u16 {
    const MAX_VALUE: u16 = u16::MAX;
    const MIN_VALUE: u16 = u16::MIN;
    fn from_u8(v: u8) -> Self {
        v as u16
    }
    fn to_u64(self) -> u64 {
        self as u64
    }
}

/// A 2-D image with `height` rows × `width` columns, row-major storage
/// with an explicit row `stride` (in elements, `stride >= width`).
///
/// Equality (`==`) compares **logical pixels only** — two images with
/// the same `height × width` content are equal even if their strides
/// (and therefore padding bytes) differ.
#[derive(Clone, Debug)]
pub struct Image<T: Pixel = u8> {
    height: usize,
    width: usize,
    stride: usize,
    data: Vec<T>,
}

impl<T: Pixel> Image<T> {
    /// A `height × width` image filled with `value`, stride == width.
    pub fn filled(height: usize, width: usize, value: T) -> Self {
        Self {
            height,
            width,
            stride: width,
            data: vec![value; height * width],
        }
    }

    /// A zero image.
    pub fn zeros(height: usize, width: usize) -> Self {
        Self::filled(height, width, T::default())
    }

    /// Wrap a row-major vector (len must equal `height * width`).
    pub fn from_vec(height: usize, width: usize, data: Vec<T>) -> Self {
        assert_eq!(
            data.len(),
            height * width,
            "from_vec: data length {} != {}x{}",
            data.len(),
            height,
            width
        );
        Self {
            height,
            width,
            stride: width,
            data,
        }
    }

    /// Build from a per-pixel function `f(row, col)`.
    pub fn from_fn(height: usize, width: usize, mut f: impl FnMut(usize, usize) -> T) -> Self {
        let mut data = Vec::with_capacity(height * width);
        for y in 0..height {
            for x in 0..width {
                data.push(f(y, x));
            }
        }
        Self::from_vec(height, width, data)
    }

    /// A copy with each row padded to `stride` elements (pad = `fill`).
    /// SIMD passes use this so 16-lane stores never cross a row end.
    pub fn with_stride(&self, stride: usize, fill: T) -> Self {
        assert!(stride >= self.width, "stride {} < width {}", stride, self.width);
        let mut data = vec![fill; self.height * stride];
        for y in 0..self.height {
            let src = self.row(y);
            data[y * stride..y * stride + self.width].copy_from_slice(src);
        }
        Self {
            height: self.height,
            width: self.width,
            stride,
            data,
        }
    }

    /// Drop any row padding, making `stride == width`.
    pub fn compact(&self) -> Self {
        if self.stride == self.width {
            return self.clone();
        }
        let mut data = Vec::with_capacity(self.height * self.width);
        for y in 0..self.height {
            data.extend_from_slice(self.row(y));
        }
        Self::from_vec(self.height, self.width, data)
    }

    pub fn height(&self) -> usize {
        self.height
    }

    pub fn width(&self) -> usize {
        self.width
    }

    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Total pixels (excludes padding).
    pub fn pixels(&self) -> usize {
        self.height * self.width
    }

    /// Row `y` as a slice of `width` elements (excludes padding).
    #[inline]
    pub fn row(&self, y: usize) -> &[T] {
        &self.data[y * self.stride..y * self.stride + self.width]
    }

    /// Row `y` including its padding (`stride` elements).
    #[inline]
    pub fn row_padded(&self, y: usize) -> &[T] {
        &self.data[y * self.stride..(y + 1) * self.stride]
    }

    #[inline]
    pub fn row_mut(&mut self, y: usize) -> &mut [T] {
        &mut self.data[y * self.stride..y * self.stride + self.width]
    }

    #[inline]
    pub fn row_padded_mut(&mut self, y: usize) -> &mut [T] {
        &mut self.data[y * self.stride..(y + 1) * self.stride]
    }

    #[inline]
    pub fn get(&self, y: usize, x: usize) -> T {
        debug_assert!(y < self.height && x < self.width);
        self.data[y * self.stride + x]
    }

    #[inline]
    pub fn set(&mut self, y: usize, x: usize, v: T) {
        debug_assert!(y < self.height && x < self.width);
        self.data[y * self.stride + x] = v;
    }

    /// Raw storage, including padding.
    pub fn raw(&self) -> &[T] {
        &self.data
    }

    pub fn raw_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    /// Borrow the whole image as an [`ImageView`] — the canonical
    /// kernel argument (also available implicitly through
    /// `From<&Image>`).
    #[inline]
    pub fn view(&self) -> ImageView<'_, T> {
        ImageView::from_slice(&self.data, self.height, self.width, self.stride)
    }

    /// Borrow the whole image as a unique mutable [`ImageViewMut`],
    /// splittable into disjoint row bands for in-place parallel writes.
    #[inline]
    pub fn view_mut(&mut self) -> ImageViewMut<'_, T> {
        ImageViewMut::from_slice_mut(&mut self.data, self.height, self.width, self.stride)
    }

    /// Row-major `height*width` copy without padding.
    pub fn to_vec(&self) -> Vec<T> {
        if self.stride == self.width {
            return self.data.clone();
        }
        let mut out = Vec::with_capacity(self.pixels());
        for y in 0..self.height {
            out.extend_from_slice(self.row(y));
        }
        out
    }

    /// Pointwise equality of the logical pixels.  Stride-correct by
    /// construction: rows are compared through the stride-aware view,
    /// so padding bytes never participate (two images that differ only
    /// in padding — e.g. a [`Image::with_stride`] copy — compare equal).
    pub fn same_pixels(&self, other: &Self) -> bool {
        self.view().same_pixels(other.view())
    }

    /// First differing *logical* pixel `(y, x, self, other)`, if any —
    /// test helper.  Like [`Image::same_pixels`], never inspects
    /// padding bytes.
    pub fn first_diff(&self, other: &Self) -> Option<(usize, usize, T, T)> {
        if self.height != other.height || self.width != other.width {
            return Some((usize::MAX, usize::MAX, T::default(), T::default()));
        }
        for y in 0..self.height {
            for x in 0..self.width {
                let (a, b) = (self.get(y, x), other.get(y, x));
                if a != b {
                    return Some((y, x, a, b));
                }
            }
        }
        None
    }

    /// Transposed copy (naive; fast versions live in
    /// [`crate::transpose`]).  Stride-correct: reads go through the
    /// row view of this image, so padded inputs transpose their
    /// logical pixels only (the result is compact).
    pub fn transposed(&self) -> Self {
        let mut out = Self::zeros(self.width, self.height);
        for y in 0..self.height {
            let row = self.row(y);
            for (x, &v) in row.iter().enumerate() {
                out.set(x, y, v);
            }
        }
        out
    }

    /// Min and max pixel value (None for empty images).
    pub fn min_max(&self) -> Option<(T, T)> {
        let mut it = (0..self.height).flat_map(|y| self.row(y).iter().copied());
        let first = it.next()?;
        let mut mn = first;
        let mut mx = first;
        for v in it {
            if v < mn {
                mn = v;
            }
            if v > mx {
                mx = v;
            }
        }
        Some((mn, mx))
    }

    /// Mean pixel value (0.0 for empty images).
    pub fn mean(&self) -> f64 {
        if self.pixels() == 0 {
            return 0.0;
        }
        let sum: u64 = (0..self.height)
            .flat_map(|y| self.row(y).iter().map(|v| v.to_u64()))
            .sum();
        sum as f64 / self.pixels() as f64
    }
}

/// Logical-pixel equality: strides and padding bytes are ignored, so a
/// padded copy ([`Image::with_stride`]) equals its compact original.
/// (The former derived `PartialEq` compared the raw backing vectors,
/// padding included — a stride bug for any comparison involving padded
/// images.)
impl<T: Pixel> PartialEq for Image<T> {
    fn eq(&self, other: &Self) -> bool {
        self.same_pixels(other)
    }
}

impl<T: Pixel> Eq for Image<T> {}

impl Image<u8> {
    /// Borrow pixels as raw bytes (requires compact stride).
    pub fn as_bytes(&self) -> &[u8] {
        assert_eq!(
            self.stride, self.width,
            "as_bytes requires a compact image; call .compact() first"
        );
        &self.data
    }

    /// Build from raw bytes, row-major.
    pub fn from_bytes(height: usize, width: usize, bytes: &[u8]) -> Self {
        Self::from_vec(height, width, bytes.to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn filled_and_get_set() {
        let mut img = Image::<u8>::filled(3, 4, 7);
        assert_eq!(img.height(), 3);
        assert_eq!(img.width(), 4);
        assert_eq!(img.get(2, 3), 7);
        img.set(1, 2, 200);
        assert_eq!(img.get(1, 2), 200);
        assert_eq!(img.pixels(), 12);
    }

    #[test]
    #[should_panic(expected = "from_vec")]
    fn from_vec_wrong_len_panics() {
        let _ = Image::<u8>::from_vec(2, 2, vec![0; 5]);
    }

    #[test]
    fn stride_round_trip() {
        let img = Image::from_fn(5, 7, |y, x| (y * 10 + x) as u8);
        let padded = img.with_stride(16, 0xFF);
        assert_eq!(padded.stride(), 16);
        assert!(padded.same_pixels(&img));
        assert_eq!(padded.row_padded(0)[7], 0xFF);
        let back = padded.compact();
        assert_eq!(back, img);
        assert_eq!(back.to_vec(), img.to_vec());
    }

    #[test]
    fn transpose_involution() {
        let img = Image::from_fn(4, 9, |y, x| (y * 16 + x) as u8);
        let t = img.transposed();
        assert_eq!(t.height(), 9);
        assert_eq!(t.width(), 4);
        assert_eq!(t.get(3, 2), img.get(2, 3));
        assert!(t.transposed().same_pixels(&img));
    }

    #[test]
    fn min_max_mean() {
        let img = Image::from_vec(2, 2, vec![1u8, 2, 3, 10]);
        assert_eq!(img.min_max(), Some((1, 10)));
        assert!((img.mean() - 4.0).abs() < 1e-12);
        let empty = Image::<u8>::zeros(0, 0);
        assert_eq!(empty.min_max(), None);
        assert_eq!(empty.mean(), 0.0);
    }

    #[test]
    fn first_diff_finds_mismatch() {
        let a = Image::from_vec(2, 2, vec![1u8, 2, 3, 4]);
        let mut b = a.clone();
        assert_eq!(a.first_diff(&b), None);
        b.set(1, 0, 9);
        assert_eq!(a.first_diff(&b), Some((1, 0, 3, 9)));
    }

    #[test]
    fn equality_and_diff_ignore_padding_bytes() {
        // regression: stride-correctness of transposed / same_pixels /
        // first_diff / == on padded images
        let img = Image::from_fn(5, 7, |y, x| (3 * y + x) as u8);
        let padded = img.with_stride(12, 0x5A);
        let padded_other_fill = img.with_stride(16, 0xA5);
        assert!(padded.same_pixels(&img));
        assert_eq!(padded.first_diff(&img), None);
        assert_eq!(padded, img, "== must ignore stride and padding");
        assert_eq!(padded, padded_other_fill, "padding fill must not matter");
        let mut tweaked = padded.clone();
        tweaked.set(4, 6, 0xFF);
        assert_ne!(tweaked, img);
        assert_eq!(tweaked.first_diff(&img), Some((4, 6, 0xFF, img.get(4, 6))));
    }

    #[test]
    fn transposed_is_stride_correct() {
        // regression: transpose of a padded image must read logical
        // rows only, never padding
        let img = Image::from_fn(4, 9, |y, x| (y * 10 + x) as u8);
        let padded = img.with_stride(16, 0xEE);
        let t = padded.transposed();
        assert_eq!((t.height(), t.width()), (9, 4));
        assert!(t.same_pixels(&img.transposed()));
        assert_eq!(t.get(8, 3), img.get(3, 8));
        // u16 as well (different element width)
        let img16 = Image::<u16>::from_fn(3, 5, |y, x| (y * 1000 + x) as u16);
        let padded16 = img16.with_stride(8, 0xBEEF);
        assert!(padded16.transposed().same_pixels(&img16.transposed()));
    }

    #[test]
    fn u16_pixels_work() {
        let img = Image::<u16>::from_fn(3, 3, |y, x| (y * 1000 + x) as u16);
        assert_eq!(img.get(2, 2), 2002);
        assert_eq!(u16::MAX_VALUE, 65535);
    }
}
