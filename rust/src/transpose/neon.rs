//! The paper's §4 NEON transpose networks, ported intrinsic-for-intrinsic.
//!
//! * [`transpose8x8_u16`] is the paper's 8×8.16 listing verbatim:
//!   4 `vtrnq_u16` + 4 `vtrnq_u32` + 8 `vcombine`/16 `vget` between 8
//!   loads and 8 stores — 16 load/store + 32 data-permutation + 16
//!   auxiliary reinterprets, the exact §4 instruction census.  Its
//!   register-only core is [`transpose8x8_regs`], used by the
//!   whole-image u16 tiling.
//! * [`transpose16x16_u8`] is the 16×16.8 network: a four-level vtrn
//!   ladder (`vtrn.8`, `vtrn.16`, `vtrn.32`, then 64-bit half exchange
//!   via `vget`/`vcombine`) — 32 load/store + 72 data-permutation,
//!   matching the §4 census (our auxiliary-reinterpret count is 64 vs
//!   the paper's 48: aux instructions are view changes the compiler may
//!   or may not materialize, and are free in the cost model either way).

use crate::neon::{Backend, U16x8, U32x4, U8x16};

/// The register-only 8×8.16 vtrn network: transposes 8 loaded row
/// registers in place (slot `i` ends up holding column `i`).  Exposed so
/// whole-image tiling can load/store straight from strided rows without
/// staging buffers (mirroring [`transpose16x16_regs`]).
pub fn transpose8x8_regs<B: Backend>(b: &mut B, rows: &mut [U16x8; 8]) {
    // 4 vtrn.16: transpose 2×2 blocks of u16
    let t0 = b.vtrnq_u16(rows[0], rows[1]);
    let t1 = b.vtrnq_u16(rows[2], rows[3]);
    let t2 = b.vtrnq_u16(rows[4], rows[5]);
    let t3 = b.vtrnq_u16(rows[6], rows[7]);

    // 4 vtrn.32: transpose 2×2 blocks of u32 (pairs of u16)
    let t00 = b.reinterpret_u32_u16(t0.0);
    let t10 = b.reinterpret_u32_u16(t1.0);
    let t20 = b.reinterpret_u32_u16(t2.0);
    let t30 = b.reinterpret_u32_u16(t3.0);
    let t01 = b.reinterpret_u32_u16(t0.1);
    let t11 = b.reinterpret_u32_u16(t1.1);
    let t21 = b.reinterpret_u32_u16(t2.1);
    let t31 = b.reinterpret_u32_u16(t3.1);
    let x0 = b.vtrnq_u32(t00, t10);
    let x1 = b.vtrnq_u32(t20, t30);
    let x2 = b.vtrnq_u32(t01, t11);
    let x3 = b.vtrnq_u32(t21, t31);

    // 2×2 transpose of u64 blocks via vcombine(vget_low/high …)
    let lo = |b: &mut B, p: U32x4, q: U32x4| {
        let l0 = b.vget_low_u32(p);
        let l1 = b.vget_low_u32(q);
        b.vcombine_u32(l0, l1)
    };
    let hi = |b: &mut B, p: U32x4, q: U32x4| {
        let h0 = b.vget_high_u32(p);
        let h1 = b.vget_high_u32(q);
        b.vcombine_u32(h0, h1)
    };

    let d0 = lo(b, x0.0, x1.0);
    rows[0] = b.reinterpret_u16_u32(d0);
    let d1 = lo(b, x2.0, x3.0);
    rows[1] = b.reinterpret_u16_u32(d1);
    let d2 = lo(b, x0.1, x1.1);
    rows[2] = b.reinterpret_u16_u32(d2);
    let d3 = lo(b, x2.1, x3.1);
    rows[3] = b.reinterpret_u16_u32(d3);
    let d4 = hi(b, x0.0, x1.0);
    rows[4] = b.reinterpret_u16_u32(d4);
    let d5 = hi(b, x2.0, x3.0);
    rows[5] = b.reinterpret_u16_u32(d5);
    let d6 = hi(b, x0.1, x1.1);
    rows[6] = b.reinterpret_u16_u32(d6);
    let d7 = hi(b, x2.1, x3.1);
    rows[7] = b.reinterpret_u16_u32(d7);
}

/// Transpose an 8×8 matrix of u16 (row-major, 64 elements).
///
/// Faithful port of the paper's §4 source listing: 8 loads, the
/// [`transpose8x8_regs`] vtrn network, 8 stores.
pub fn transpose8x8_u16<B: Backend>(b: &mut B, src: &[u16], dst: &mut [u16]) {
    debug_assert!(src.len() >= 64 && dst.len() >= 64);
    let mut rows: [U16x8; 8] = [U16x8([0; 8]); 8];
    for (i, row) in rows.iter_mut().enumerate() {
        *row = b.vld1q_u16(&src[i * 8..]);
    }
    transpose8x8_regs(b, &mut rows);
    for (i, row) in rows.iter().enumerate() {
        b.vst1q_u16(&mut dst[i * 8..], *row);
    }
}

/// Transpose a 16×16 matrix of u8 (row-major, 256 elements).
///
/// Four-level vtrn ladder; level `d` transposes 2^d-byte blocks between
/// register slots `i` and `i + 2^d`, results written back in place, so
/// after all levels slot `i` holds column `i`.
pub fn transpose16x16_u8<B: Backend>(b: &mut B, src: &[u8], dst: &mut [u8]) {
    debug_assert!(src.len() >= 256 && dst.len() >= 256);
    let mut rows: [U8x16; 16] = [U8x16([0; 16]); 16];
    for (i, row) in rows.iter_mut().enumerate() {
        *row = b.vld1q_u8(&src[i * 16..]);
    }
    transpose16x16_regs(b, &mut rows);
    for (i, row) in rows.iter().enumerate() {
        b.vst1q_u8(&mut dst[i * 16..], *row);
    }
}

/// The register-only 16×16 vtrn ladder: transposes 16 loaded row
/// registers in place (slot `i` ends up holding column `i`).  Exposed so
/// whole-image tiling can load/store straight from strided rows without
/// staging buffers.
pub fn transpose16x16_regs<B: Backend>(b: &mut B, rows: &mut [U8x16; 16]) {
    // level 0: vtrn.8 between slots (i, i+1)
    for i in (0..16).step_by(2) {
        let (x, y) = b.vtrnq_u8(rows[i], rows[i + 1]);
        rows[i] = x;
        rows[i + 1] = y;
    }
    // level 1: vtrn.16 between slots (i, i+2)
    for g in (0..16).step_by(4) {
        for i in g..g + 2 {
            let a = b.reinterpret_u16_u8(rows[i]);
            let c = b.reinterpret_u16_u8(rows[i + 2]);
            let (x, y) = b.vtrnq_u16(a, c);
            rows[i] = b.reinterpret_u8_u16(x);
            rows[i + 2] = b.reinterpret_u8_u16(y);
        }
    }
    // level 2: vtrn.32 between slots (i, i+4)
    for g in (0..16).step_by(8) {
        for i in g..g + 4 {
            let a = b.reinterpret_u32_u8(rows[i]);
            let c = b.reinterpret_u32_u8(rows[i + 4]);
            let (x, y) = b.vtrnq_u32(a, c);
            rows[i] = b.reinterpret_u8_u32(x);
            rows[i + 4] = b.reinterpret_u8_u32(y);
        }
    }
    // level 3: 64-bit half exchange between slots (i, i+8) via
    // vget/vcombine (the paper's way of writing vtrn.64, which A32 lacks)
    for i in 0..8 {
        let a = b.reinterpret_u32_u8(rows[i]);
        let c = b.reinterpret_u32_u8(rows[i + 8]);
        let alo = b.vget_low_u32(a);
        let ahi = b.vget_high_u32(a);
        let clo = b.vget_low_u32(c);
        let chi = b.vget_high_u32(c);
        let lo = b.vcombine_u32(alo, clo);
        let hi = b.vcombine_u32(ahi, chi);
        rows[i] = b.reinterpret_u8_u32(lo);
        rows[i + 8] = b.reinterpret_u8_u32(hi);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::neon::{Counting, InstrClass, Native};

    fn want_t<T: Copy>(src: &[T], n: usize) -> Vec<T> {
        (0..n * n).map(|i| src[(i % n) * n + i / n]).collect()
    }

    #[test]
    fn neon_8x8_u16_matches_scalar() {
        let src: Vec<u16> = (0..64).map(|i| (i * 321) as u16).collect();
        let mut dst = vec![0u16; 64];
        transpose8x8_u16(&mut Native, &src, &mut dst);
        assert_eq!(dst, want_t(&src, 8));
    }

    #[test]
    fn neon_16x16_u8_matches_scalar() {
        let src: Vec<u8> = (0..=255).map(|i| (i as u32 * 37 % 251) as u8).collect();
        let mut dst = vec![0u8; 256];
        transpose16x16_u8(&mut Native, &src, &mut dst);
        assert_eq!(dst, want_t(&src, 16));
    }

    #[test]
    fn regs_8x8_is_involution() {
        let mut rows: [U16x8; 8] =
            std::array::from_fn(|i| U16x8(std::array::from_fn(|j| (i * 8 + j) as u16)));
        let orig = rows;
        transpose8x8_regs(&mut Native, &mut rows);
        // slot i holds column i
        for (i, r) in rows.iter().enumerate() {
            for (j, &v) in r.0.iter().enumerate() {
                assert_eq!(v, orig[j].0[i]);
            }
        }
        transpose8x8_regs(&mut Native, &mut rows);
        assert_eq!(rows, orig);
    }

    #[test]
    fn paper_census_8x8() {
        // §4: "64 instructions: 16 load/store, 32 data permutation and 16
        // auxiliary instructions"
        let src: Vec<u16> = (0..64).collect();
        let mut dst = vec![0u16; 64];
        let mut c = Counting::new();
        transpose8x8_u16(&mut c, &src, &mut dst);
        let m = &c.mix;
        let loadstore = m.get(InstrClass::SimdLoad) + m.get(InstrClass::SimdStore);
        let perm = m.get(InstrClass::SimdPermute) + m.get(InstrClass::SimdCombine);
        assert_eq!(loadstore, 16);
        assert_eq!(perm, 32);
        assert_eq!(m.get(InstrClass::SimdReinterpret), 16);
        assert_eq!(m.scalar_total(), 0);
    }

    #[test]
    fn paper_census_16x16() {
        // §4: "152 instructions (32 load/store, 72 data permutation and
        // 48 auxiliary...)" — we match load/store and permutation counts;
        // reinterpret (free) count differs by view bookkeeping.
        let src: Vec<u8> = (0..=255).collect();
        let mut dst = vec![0u8; 256];
        let mut c = Counting::new();
        transpose16x16_u8(&mut c, &src, &mut dst);
        let m = &c.mix;
        let loadstore = m.get(InstrClass::SimdLoad) + m.get(InstrClass::SimdStore);
        let perm = m.get(InstrClass::SimdPermute) + m.get(InstrClass::SimdCombine);
        assert_eq!(loadstore, 32);
        assert_eq!(perm, 72);
        assert_eq!(m.scalar_total(), 0);
    }

    #[test]
    fn transpose_is_involution() {
        let src: Vec<u8> = (0..=255).map(|i| (i as u32 * 89 % 256) as u8).collect();
        let mut once = vec![0u8; 256];
        let mut twice = vec![0u8; 256];
        transpose16x16_u8(&mut Native, &src, &mut once);
        transpose16x16_u8(&mut Native, &once, &mut twice);
        assert_eq!(twice, src);
    }
}
