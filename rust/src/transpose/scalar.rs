//! Scalar (non-SIMD) tile transposes — the paper's Table 1 baselines.
//!
//! These are deliberately the straightforward element loops a compiler
//! sees without vectorization hints; the instruction accounting (64
//! loads + 64 stores for 8×8.16, 256 + 256 for 16×16.8) feeds the cost
//! model's "without SIMD" column.

use crate::neon::Backend;

/// 8×8 u16 tile transpose, element by element.
///
/// `src` and `dst` are row-major 64-element buffers; `src_stride` /
/// `dst_stride` are row strides in elements (8 for a dense tile).
pub fn transpose8x8_u16_scalar<B: Backend>(
    b: &mut B,
    src: &[u16],
    dst: &mut [u16],
) {
    debug_assert!(src.len() >= 64 && dst.len() >= 64);
    for y in 0..8 {
        for x in 0..8 {
            let v = b.scalar_load_u16(src, y * 8 + x);
            b.scalar_store_u16(dst, x * 8 + y, v);
        }
    }
}

/// 16×16 u8 tile transpose, element by element.
pub fn transpose16x16_u8_scalar<B: Backend>(b: &mut B, src: &[u8], dst: &mut [u8]) {
    debug_assert!(src.len() >= 256 && dst.len() >= 256);
    for y in 0..16 {
        for x in 0..16 {
            let v = b.scalar_load_u8(src, y * 16 + x);
            b.scalar_store_u8(dst, x * 16 + y, v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::neon::{Counting, InstrClass, Native};

    #[test]
    fn scalar_8x8_transposes() {
        let src: Vec<u16> = (0..64).collect();
        let mut dst = vec![0u16; 64];
        transpose8x8_u16_scalar(&mut Native, &src, &mut dst);
        for y in 0..8 {
            for x in 0..8 {
                assert_eq!(dst[x * 8 + y], src[y * 8 + x]);
            }
        }
    }

    #[test]
    fn scalar_16x16_transposes() {
        let src: Vec<u8> = (0..=255).collect();
        let mut dst = vec![0u8; 256];
        transpose16x16_u8_scalar(&mut Native, &src, &mut dst);
        for y in 0..16 {
            for x in 0..16 {
                assert_eq!(dst[x * 16 + y], src[y * 16 + x]);
            }
        }
    }

    #[test]
    fn instruction_counts_match_paper_baseline() {
        // Table 1 baseline mixes: pure element loads + stores.
        let src: Vec<u16> = (0..64).collect();
        let mut dst = vec![0u16; 64];
        let mut c = Counting::new();
        transpose8x8_u16_scalar(&mut c, &src, &mut dst);
        assert_eq!(c.mix.get(InstrClass::ScalarLoad), 64);
        assert_eq!(c.mix.get(InstrClass::ScalarStore), 64);
        assert_eq!(c.mix.simd_total(), 0);

        let src8: Vec<u8> = (0..=255).collect();
        let mut dst8 = vec![0u8; 256];
        let mut c = Counting::new();
        transpose16x16_u8_scalar(&mut c, &src8, &mut dst8);
        assert_eq!(c.mix.get(InstrClass::ScalarLoad), 256);
        assert_eq!(c.mix.get(InstrClass::ScalarStore), 256);
    }
}
