//! Matrix / image transpose (paper §4).
//!
//! * [`scalar`] — element-wise transpose, the paper's "without SIMD"
//!   baseline for Table 1.
//! * [`neon`] — the paper's vtrn networks: 8×8.16 in 64 instructions
//!   (16 load/store + 32 permutation + 16 free reinterprets) and
//!   16×16.8 in 152 instructions (32 + 72 + 48), exactly the §4 counts.
//! * Whole-image transposes tile the NEON networks over the image with
//!   scalar edge handling: [`transpose_image`] uses 16×16.8 tiles for
//!   `u8`, [`transpose_image_u16`] uses 8×8.16 tiles for `u16` — these
//!   are what the baseline *vertical* morphology pass (§5.2.1) uses at
//!   each depth, dispatched through
//!   [`crate::morphology::MorphPixel::transpose_image`].
//! * **Band forms** ([`transpose_band_into`] /
//!   [`transpose_band_u16_into`]) transpose one source row band
//!   `[y0, y1)` into the matching destination **column stripe**
//!   (`ImageViewMut::split_cols_mut`): source tile-rows are independent,
//!   so the banded executor (`morphology::parallel::
//!   transpose_image_banded_into`) forks one band job per stripe and the
//!   §5.2.1 sandwich runs end-to-end on the `BandPool`.  With one band
//!   covering `[0, h)` the band form **is** the sequential driver —
//!   same tiles, same scalar edges, same counted instruction mix.
//!
//! Every driver has an `_into` form writing a caller-provided
//! [`ImageViewMut`] (the plan arena owns the sandwich buffers) and an
//! allocating wrapper built on it.

pub mod neon;
pub mod scalar;

use crate::image::{Image, ImageView, ImageViewMut};
use crate::neon::Backend;
use std::ops::Range;

pub use neon::{transpose16x16_u8, transpose8x8_u16};
pub use scalar::{transpose16x16_u8_scalar, transpose8x8_u16_scalar};

/// Transpose a u8 image using 16×16 NEON tiles for the aligned interior
/// and scalar copies for the right/bottom edges.  Reads any borrowed
/// strided [`ImageView`] (a `&Image` coerces).
pub fn transpose_image<'a, B: Backend>(b: &mut B, img: impl Into<ImageView<'a, u8>>) -> Image<u8> {
    let img = img.into();
    let mut out = Image::zeros(img.width(), img.height());
    transpose_image_into(b, img, out.view_mut());
    out
}

/// [`transpose_image`] writing into a caller-provided `w × h`
/// destination view — the zero-allocation form the plan executor's
/// §5.2.1 sandwich reuses its preallocated buffers through.
pub fn transpose_image_into<'a, B: Backend>(
    b: &mut B,
    img: impl Into<ImageView<'a, u8>>,
    mut out: ImageViewMut<'_, u8>,
) {
    let img = img.into();
    let h = img.height();
    debug_assert_eq!((out.height(), out.width()), (img.width(), h));
    transpose_band_into(b, img, &mut out, 0..h);
}

/// Transpose source row band `[y0, y1)` of a u8 image into `out`, the
/// matching `w × (y1−y0)` destination **column stripe** (columns
/// `[y0, y1)` of the transposed image, e.g. one
/// `ImageViewMut::split_cols_mut` stripe).  `img` is the *full* source
/// view.
///
/// Tile rows fully inside the band run the 16×16.8 NEON network;
/// leading/trailing partial tile rows (only when a band boundary is not
/// 16-aligned) and the right-edge columns fall back to scalar, exactly
/// like the whole-image driver's edges.  One band covering `[0, h)`
/// reproduces [`transpose_image_into`]'s instruction mix verbatim;
/// each band accounts its own `(y1−y0)·w` share of the memory stream.
pub fn transpose_band_into<'a, B: Backend>(
    b: &mut B,
    img: impl Into<ImageView<'a, u8>>,
    out: &mut ImageViewMut<'_, u8>,
    band: Range<usize>,
) {
    let img = img.into();
    let (h, w) = (img.height(), img.width());
    let (y0, y1) = (band.start, band.end);
    debug_assert!(y0 <= y1 && y1 <= h, "band {band:?} out of 0..{h}");
    debug_assert_eq!((out.height(), out.width()), (w, y1 - y0));
    if y0 == y1 || w == 0 {
        return;
    }
    b.record_stream(((y1 - y0) * w) as u64, ((y1 - y0) * w) as u64);

    let tw = w - w % 16;
    // tile rows fully inside the band (16-aligned bands make this the
    // whole band; the image's own bottom remainder trails the last one)
    let t0 = (y0.div_ceil(16) * 16).min(y1);
    let t1 = t0 + (y1 - t0) / 16 * 16;
    for by in (t0..t1).step_by(16) {
        for bx in (0..tw).step_by(16) {
            let mut rows = [crate::neon::U8x16([0; 16]); 16];
            for (r, reg) in rows.iter_mut().enumerate() {
                *reg = b.vld1q_u8(&img.row(by + r)[bx..]);
            }
            neon::transpose16x16_regs(b, &mut rows);
            for (r, reg) in rows.iter().enumerate() {
                b.vst1q_u8(&mut out.row_mut(bx + r)[by - y0..], *reg);
            }
        }
    }
    // partial tile rows at the band boundaries (accounted as scalar)
    for y in (y0..t0).chain(t1..y1) {
        for x in 0..tw {
            let v = b.scalar_load_u8(img.row(y), x);
            b.scalar_store_u8(out.row_mut(x), y - y0, v);
        }
    }
    // right edge columns
    for y in y0..y1 {
        for x in tw..w {
            let v = b.scalar_load_u8(img.row(y), x);
            b.scalar_store_u8(out.row_mut(x), y - y0, v);
        }
    }
}

/// Transpose a u16 image using the paper's 8×8.16 NEON tiles for the
/// aligned interior and scalar copies for the right/bottom edges — the
/// 16-bit counterpart of [`transpose_image`].
pub fn transpose_image_u16<'a, B: Backend>(
    b: &mut B,
    img: impl Into<ImageView<'a, u16>>,
) -> Image<u16> {
    let img = img.into();
    let mut out = Image::zeros(img.width(), img.height());
    transpose_image_u16_into(b, img, out.view_mut());
    out
}

/// [`transpose_image_u16`] writing into a caller-provided `w × h`
/// destination view.
pub fn transpose_image_u16_into<'a, B: Backend>(
    b: &mut B,
    img: impl Into<ImageView<'a, u16>>,
    mut out: ImageViewMut<'_, u16>,
) {
    let img = img.into();
    let h = img.height();
    debug_assert_eq!((out.height(), out.width()), (img.width(), h));
    transpose_band_u16_into(b, img, &mut out, 0..h);
}

/// The u16 band form: source row band `[y0, y1)` into the matching
/// destination column stripe via 8×8.16 tiles — see
/// [`transpose_band_into`] for the geometry contract.
pub fn transpose_band_u16_into<'a, B: Backend>(
    b: &mut B,
    img: impl Into<ImageView<'a, u16>>,
    out: &mut ImageViewMut<'_, u16>,
    band: Range<usize>,
) {
    let img = img.into();
    let (h, w) = (img.height(), img.width());
    let (y0, y1) = (band.start, band.end);
    debug_assert!(y0 <= y1 && y1 <= h, "band {band:?} out of 0..{h}");
    debug_assert_eq!((out.height(), out.width()), (w, y1 - y0));
    if y0 == y1 || w == 0 {
        return;
    }
    b.record_stream((2 * (y1 - y0) * w) as u64, (2 * (y1 - y0) * w) as u64);

    let tw = w - w % 8;
    let t0 = (y0.div_ceil(8) * 8).min(y1);
    let t1 = t0 + (y1 - t0) / 8 * 8;
    for by in (t0..t1).step_by(8) {
        for bx in (0..tw).step_by(8) {
            let mut rows = [crate::neon::U16x8([0; 8]); 8];
            for (r, reg) in rows.iter_mut().enumerate() {
                *reg = b.vld1q_u16(&img.row(by + r)[bx..]);
            }
            neon::transpose8x8_regs(b, &mut rows);
            for (r, reg) in rows.iter().enumerate() {
                b.vst1q_u16(&mut out.row_mut(bx + r)[by - y0..], *reg);
            }
        }
    }
    for y in (y0..t0).chain(t1..y1) {
        for x in 0..tw {
            let v = b.scalar_load_u16(img.row(y), x);
            b.scalar_store_u16(out.row_mut(x), y - y0, v);
        }
    }
    for y in y0..y1 {
        for x in tw..w {
            let v = b.scalar_load_u16(img.row(y), x);
            b.scalar_store_u16(out.row_mut(x), y - y0, v);
        }
    }
}

/// Scalar whole-image transpose (baseline for benches).
pub fn transpose_image_scalar<'a, B: Backend>(
    b: &mut B,
    img: impl Into<ImageView<'a, u8>>,
) -> Image<u8> {
    let img = img.into();
    let mut out = Image::zeros(img.width(), img.height());
    transpose_image_scalar_into(b, img, out.view_mut());
    out
}

/// [`transpose_image_scalar`] writing into a caller-provided `w × h`
/// destination view — same signature shape as the tiled `_into` drivers
/// so benches/tests reuse one buffer across repetitions.
pub fn transpose_image_scalar_into<'a, B: Backend>(
    b: &mut B,
    img: impl Into<ImageView<'a, u8>>,
    mut out: ImageViewMut<'_, u8>,
) {
    let img = img.into();
    let (h, w) = (img.height(), img.width());
    debug_assert_eq!((out.height(), out.width()), (w, h));
    b.record_stream((h * w) as u64, (h * w) as u64);
    for y in 0..h {
        for x in 0..w {
            let v = b.scalar_load_u8(img.row(y), x);
            b.scalar_store_u8(out.row_mut(x), y, v);
        }
    }
}

/// Cache-blocked scalar transpose (the fair non-SIMD comparator for
/// large images, where naive scalar thrashes the cache).
pub fn transpose_image_blocked<'a, B: Backend>(
    b: &mut B,
    img: impl Into<ImageView<'a, u8>>,
    block: usize,
) -> Image<u8> {
    let img = img.into();
    let mut out = Image::zeros(img.width(), img.height());
    transpose_image_blocked_into(b, img, out.view_mut(), block);
    out
}

/// [`transpose_image_blocked`] writing into a caller-provided `w × h`
/// destination view.
pub fn transpose_image_blocked_into<'a, B: Backend>(
    b: &mut B,
    img: impl Into<ImageView<'a, u8>>,
    mut out: ImageViewMut<'_, u8>,
    block: usize,
) {
    let img = img.into();
    let block = block.max(1);
    let (h, w) = (img.height(), img.width());
    debug_assert_eq!((out.height(), out.width()), (w, h));
    b.record_stream((h * w) as u64, (h * w) as u64);
    for by in (0..h).step_by(block) {
        for bx in (0..w).step_by(block) {
            for y in by..(by + block).min(h) {
                for x in bx..(bx + block).min(w) {
                    let v = b.scalar_load_u8(img.row(y), x);
                    b.scalar_store_u8(out.row_mut(x), y, v);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth;
    use crate::neon::{Counting, Native};

    #[test]
    fn image_transpose_matches_naive_all_shapes() {
        for &(h, w) in &[(16, 16), (32, 48), (17, 33), (600, 800), (1, 5), (15, 15)] {
            let img = synth::noise(h, w, (h * 1000 + w) as u64);
            let want = img.transposed();
            let got = transpose_image(&mut Native, &img);
            assert!(got.same_pixels(&want), "neon tiled {h}x{w}");
            let got_s = transpose_image_scalar(&mut Native, &img);
            assert!(got_s.same_pixels(&want), "scalar {h}x{w}");
            let got_b = transpose_image_blocked(&mut Native, &img, 32);
            assert!(got_b.same_pixels(&want), "blocked {h}x{w}");
        }
    }

    #[test]
    fn u16_image_transpose_matches_naive_all_shapes() {
        for &(h, w) in &[(8, 8), (16, 24), (17, 33), (100, 80), (1, 5), (7, 7)] {
            let img = synth::noise_u16(h, w, (h * 1000 + w) as u64);
            let want = img.transposed();
            let got = transpose_image_u16(&mut Native, &img);
            assert!(got.same_pixels(&want), "neon 8x8.16 tiled {h}x{w}");
        }
    }

    #[test]
    fn tiled_transpose_reads_strided_and_sub_views() {
        // view contract: padded strides and ROI sub-rectangles transpose
        // identically to their compact copies
        let img = synth::noise(40, 56, 21);
        let padded = img.with_stride(64, 0xDD);
        assert!(transpose_image(&mut Native, &padded).same_pixels(&img.transposed()));
        let sub = img.view().sub_rect(3, 5, 33, 48);
        let want = sub.to_image().transposed();
        assert!(transpose_image(&mut Native, sub).same_pixels(&want));
        let img16 = synth::noise_u16(24, 40, 4);
        let padded16 = img16.with_stride(48, 7);
        assert!(transpose_image_u16(&mut Native, &padded16).same_pixels(&img16.transposed()));
    }

    #[test]
    fn tiled_transpose_instruction_mix_is_mostly_simd() {
        let img = synth::noise(64, 64, 9);
        let mut c = Counting::new();
        let _ = transpose_image(&mut c, &img);
        // 16 tiles * (16 ld + 16 st) vector mem ops, zero scalar loads
        assert_eq!(c.mix.get(crate::neon::InstrClass::SimdLoad), 16 * 16);
        assert_eq!(c.mix.get(crate::neon::InstrClass::ScalarLoad), 0);
    }

    #[test]
    fn u16_tiled_transpose_uses_8x8_tiles() {
        // 64x64 u16 → (64/8)^2 = 64 tiles × 8 loads = 512 vector loads
        let img = synth::noise_u16(64, 64, 9);
        let mut c = Counting::new();
        let _ = transpose_image_u16(&mut c, &img);
        assert_eq!(c.mix.get(crate::neon::InstrClass::SimdLoad), 64 * 8);
        assert_eq!(c.mix.get(crate::neon::InstrClass::SimdStore), 64 * 8);
        assert_eq!(c.mix.get(crate::neon::InstrClass::ScalarLoad), 0);
    }

    #[test]
    fn edges_fall_back_to_scalar() {
        let img = synth::noise(18, 18, 10);
        let mut c = Counting::new();
        let got = transpose_image(&mut c, &img);
        assert!(got.same_pixels(&img.transposed()));
        // 1 NEON tile + (18*18 - 256) scalar edge pixels
        assert_eq!(c.mix.get(crate::neon::InstrClass::ScalarLoad), (18 * 18 - 256) as u64);
    }

    #[test]
    fn u16_edges_fall_back_to_scalar() {
        let img = synth::noise_u16(10, 10, 10);
        let mut c = Counting::new();
        let got = transpose_image_u16(&mut c, &img);
        assert!(got.same_pixels(&img.transposed()));
        // 1 NEON 8x8 tile + (10*10 - 64) scalar edge pixels
        assert_eq!(c.mix.get(crate::neon::InstrClass::ScalarLoad), (10 * 10 - 64) as u64);
    }

    /// Run the u8 band kernel over every band of `plan` into
    /// `split_cols_mut` stripes of one destination (sequentially here;
    /// the threaded form lives in `morphology::parallel`).
    fn banded_u8(img: &Image<u8>, plan: &[std::ops::Range<usize>]) -> Image<u8> {
        let mut out = Image::zeros(img.width(), img.height());
        let stripes = out.view_mut().split_cols_mut(plan);
        for (band, mut stripe) in plan.iter().cloned().zip(stripes) {
            transpose_band_into(&mut Native, img, &mut stripe, band);
        }
        out
    }

    #[test]
    fn band_form_matches_whole_image_any_partition() {
        for &(h, w) in &[(64, 48), (50, 33), (17, 16), (1, 20), (3, 3), (100, 7)] {
            let img = synth::noise(h, w, (h * 77 + w) as u64);
            let want = img.transposed();
            // aligned, unaligned, single and per-row partitions
            let plans: Vec<Vec<std::ops::Range<usize>>> = vec![
                vec![0..h],
                crate::morphology::parallel::split_bands_aligned(h, 3, 16),
                crate::morphology::parallel::split_bands(h, 4),
                (0..h).map(|y| y..y + 1).collect(),
            ];
            for plan in plans {
                let got = banded_u8(&img, &plan);
                assert!(got.same_pixels(&want), "{h}x{w} plan {plan:?}");
            }
        }
    }

    #[test]
    fn band_form_u16_matches_whole_image() {
        let img = synth::noise_u16(37, 29, 5);
        let want = img.transposed();
        for parts in [1usize, 2, 5, 37] {
            let plan = crate::morphology::parallel::split_bands_aligned(37, parts, 8);
            let mut out = Image::zeros(29, 37);
            let stripes = out.view_mut().split_cols_mut(&plan);
            for (band, mut stripe) in plan.iter().cloned().zip(stripes) {
                transpose_band_u16_into(&mut Native, &img, &mut stripe, band);
            }
            assert!(got_same(&out, &want), "parts={parts}");
        }
        fn got_same(a: &Image<u16>, b: &Image<u16>) -> bool {
            a.same_pixels(b)
        }
    }

    #[test]
    fn single_band_counts_exactly_like_sequential() {
        // the band form with one [0, h) band must account the identical
        // instruction mix (tiles, edges, stream) as the whole-image
        // driver — this is what keeps the cost model honest
        let img = synth::noise(50, 33, 8);
        let mut want = Counting::new();
        let _ = transpose_image(&mut want, &img);
        let mut got = Counting::new();
        let mut out = Image::zeros(33, 50);
        {
            let mut v = out.view_mut();
            transpose_band_into(&mut got, &img, &mut v, 0..50);
        }
        assert_eq!(got.mix, want.mix);
        let img16 = synth::noise_u16(26, 19, 9);
        let mut want16 = Counting::new();
        let _ = transpose_image_u16(&mut want16, &img16);
        let mut got16 = Counting::new();
        let mut out16 = Image::zeros(19, 26);
        {
            let mut v = out16.view_mut();
            transpose_band_u16_into(&mut got16, &img16, &mut v, 0..26);
        }
        assert_eq!(got16.mix, want16.mix);
    }

    #[test]
    fn into_forms_match_allocating_forms() {
        let img = synth::noise(21, 34, 3);
        let want = img.transposed();
        let mut out = Image::zeros(34, 21);
        transpose_image_scalar_into(&mut Native, &img, out.view_mut());
        assert!(out.same_pixels(&want));
        let mut out2 = Image::zeros(34, 21);
        transpose_image_blocked_into(&mut Native, &img, out2.view_mut(), 16);
        assert!(out2.same_pixels(&want));
    }
}
