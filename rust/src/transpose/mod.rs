//! Matrix / image transpose (paper §4).
//!
//! * [`scalar`] — element-wise transpose, the paper's "without SIMD"
//!   baseline for Table 1.
//! * [`neon`] — the paper's vtrn networks: 8×8.16 in 64 instructions
//!   (16 load/store + 32 permutation + 16 free reinterprets) and
//!   16×16.8 in 152 instructions (32 + 72 + 48), exactly the §4 counts.
//! * Whole-image transposes tile the NEON networks over the image with
//!   scalar edge handling: [`transpose_image`] uses 16×16.8 tiles for
//!   `u8`, [`transpose_image_u16`] uses 8×8.16 tiles for `u16` — these
//!   are what the baseline *vertical* morphology pass (§5.2.1) uses at
//!   each depth, dispatched through
//!   [`crate::morphology::MorphPixel::transpose_image`].

pub mod neon;
pub mod scalar;

use crate::image::{Image, ImageView, ImageViewMut};
use crate::neon::Backend;

pub use neon::{transpose16x16_u8, transpose8x8_u16};
pub use scalar::{transpose16x16_u8_scalar, transpose8x8_u16_scalar};

/// Transpose a u8 image using 16×16 NEON tiles for the aligned interior
/// and scalar copies for the right/bottom edges.  Reads any borrowed
/// strided [`ImageView`] (a `&Image` coerces).
pub fn transpose_image<'a, B: Backend>(b: &mut B, img: impl Into<ImageView<'a, u8>>) -> Image<u8> {
    let img = img.into();
    let mut out = Image::zeros(img.width(), img.height());
    transpose_image_into(b, img, out.view_mut());
    out
}

/// [`transpose_image`] writing into a caller-provided `w × h`
/// destination view — the zero-allocation form the plan executor's
/// §5.2.1 sandwich reuses its preallocated buffers through.
pub fn transpose_image_into<'a, B: Backend>(
    b: &mut B,
    img: impl Into<ImageView<'a, u8>>,
    mut out: ImageViewMut<'_, u8>,
) {
    let img = img.into();
    let (h, w) = (img.height(), img.width());
    debug_assert_eq!((out.height(), out.width()), (w, h));
    b.record_stream((h * w) as u64, (h * w) as u64);

    let th = h - h % 16;
    let tw = w - w % 16;
    // interior: 16x16 NEON tiles, loaded/stored directly from the
    // strided rows (no staging copies — EXPERIMENTS.md §Perf iter. 2)
    for by in (0..th).step_by(16) {
        for bx in (0..tw).step_by(16) {
            let mut rows = [crate::neon::U8x16([0; 16]); 16];
            for (r, reg) in rows.iter_mut().enumerate() {
                *reg = b.vld1q_u8(&img.row(by + r)[bx..]);
            }
            neon::transpose16x16_regs(b, &mut rows);
            for (r, reg) in rows.iter().enumerate() {
                b.vst1q_u8(&mut out.row_mut(bx + r)[by..], *reg);
            }
        }
    }
    // right edge columns (accounted as scalar work)
    for y in 0..h {
        for x in tw..w {
            let v = b.scalar_load_u8(img.row(y), x);
            b.scalar_store_u8(out.row_mut(x), y, v);
        }
    }
    // bottom edge rows (excluding the corner already done above)
    for y in th..h {
        for x in 0..tw {
            let v = b.scalar_load_u8(img.row(y), x);
            b.scalar_store_u8(out.row_mut(x), y, v);
        }
    }
}

/// Transpose a u16 image using the paper's 8×8.16 NEON tiles for the
/// aligned interior and scalar copies for the right/bottom edges — the
/// 16-bit counterpart of [`transpose_image`].
pub fn transpose_image_u16<'a, B: Backend>(
    b: &mut B,
    img: impl Into<ImageView<'a, u16>>,
) -> Image<u16> {
    let img = img.into();
    let mut out = Image::zeros(img.width(), img.height());
    transpose_image_u16_into(b, img, out.view_mut());
    out
}

/// [`transpose_image_u16`] writing into a caller-provided `w × h`
/// destination view.
pub fn transpose_image_u16_into<'a, B: Backend>(
    b: &mut B,
    img: impl Into<ImageView<'a, u16>>,
    mut out: ImageViewMut<'_, u16>,
) {
    let img = img.into();
    let (h, w) = (img.height(), img.width());
    debug_assert_eq!((out.height(), out.width()), (w, h));
    b.record_stream((2 * h * w) as u64, (2 * h * w) as u64);

    let th = h - h % 8;
    let tw = w - w % 8;
    for by in (0..th).step_by(8) {
        for bx in (0..tw).step_by(8) {
            let mut rows = [crate::neon::U16x8([0; 8]); 8];
            for (r, reg) in rows.iter_mut().enumerate() {
                *reg = b.vld1q_u16(&img.row(by + r)[bx..]);
            }
            neon::transpose8x8_regs(b, &mut rows);
            for (r, reg) in rows.iter().enumerate() {
                b.vst1q_u16(&mut out.row_mut(bx + r)[by..], *reg);
            }
        }
    }
    for y in 0..h {
        for x in tw..w {
            let v = b.scalar_load_u16(img.row(y), x);
            b.scalar_store_u16(out.row_mut(x), y, v);
        }
    }
    for y in th..h {
        for x in 0..tw {
            let v = b.scalar_load_u16(img.row(y), x);
            b.scalar_store_u16(out.row_mut(x), y, v);
        }
    }
}

/// Scalar whole-image transpose (baseline for benches).
pub fn transpose_image_scalar<'a, B: Backend>(
    b: &mut B,
    img: impl Into<ImageView<'a, u8>>,
) -> Image<u8> {
    let img = img.into();
    let (h, w) = (img.height(), img.width());
    let mut out = Image::zeros(w, h);
    b.record_stream((h * w) as u64, (h * w) as u64);
    for y in 0..h {
        for x in 0..w {
            let v = b.scalar_load_u8(img.row(y), x);
            b.scalar_store_u8(out.row_mut(x), y, v);
        }
    }
    out
}

/// Cache-blocked scalar transpose (the fair non-SIMD comparator for
/// large images, where naive scalar thrashes the cache).
pub fn transpose_image_blocked<'a, B: Backend>(
    b: &mut B,
    img: impl Into<ImageView<'a, u8>>,
    block: usize,
) -> Image<u8> {
    let img = img.into();
    let block = block.max(1);
    let (h, w) = (img.height(), img.width());
    let mut out = Image::zeros(w, h);
    b.record_stream((h * w) as u64, (h * w) as u64);
    for by in (0..h).step_by(block) {
        for bx in (0..w).step_by(block) {
            for y in by..(by + block).min(h) {
                for x in bx..(bx + block).min(w) {
                    let v = b.scalar_load_u8(img.row(y), x);
                    b.scalar_store_u8(out.row_mut(x), y, v);
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth;
    use crate::neon::{Counting, Native};

    #[test]
    fn image_transpose_matches_naive_all_shapes() {
        for &(h, w) in &[(16, 16), (32, 48), (17, 33), (600, 800), (1, 5), (15, 15)] {
            let img = synth::noise(h, w, (h * 1000 + w) as u64);
            let want = img.transposed();
            let got = transpose_image(&mut Native, &img);
            assert!(got.same_pixels(&want), "neon tiled {h}x{w}");
            let got_s = transpose_image_scalar(&mut Native, &img);
            assert!(got_s.same_pixels(&want), "scalar {h}x{w}");
            let got_b = transpose_image_blocked(&mut Native, &img, 32);
            assert!(got_b.same_pixels(&want), "blocked {h}x{w}");
        }
    }

    #[test]
    fn u16_image_transpose_matches_naive_all_shapes() {
        for &(h, w) in &[(8, 8), (16, 24), (17, 33), (100, 80), (1, 5), (7, 7)] {
            let img = synth::noise_u16(h, w, (h * 1000 + w) as u64);
            let want = img.transposed();
            let got = transpose_image_u16(&mut Native, &img);
            assert!(got.same_pixels(&want), "neon 8x8.16 tiled {h}x{w}");
        }
    }

    #[test]
    fn tiled_transpose_reads_strided_and_sub_views() {
        // view contract: padded strides and ROI sub-rectangles transpose
        // identically to their compact copies
        let img = synth::noise(40, 56, 21);
        let padded = img.with_stride(64, 0xDD);
        assert!(transpose_image(&mut Native, &padded).same_pixels(&img.transposed()));
        let sub = img.view().sub_rect(3, 5, 33, 48);
        let want = sub.to_image().transposed();
        assert!(transpose_image(&mut Native, sub).same_pixels(&want));
        let img16 = synth::noise_u16(24, 40, 4);
        let padded16 = img16.with_stride(48, 7);
        assert!(transpose_image_u16(&mut Native, &padded16).same_pixels(&img16.transposed()));
    }

    #[test]
    fn tiled_transpose_instruction_mix_is_mostly_simd() {
        let img = synth::noise(64, 64, 9);
        let mut c = Counting::new();
        let _ = transpose_image(&mut c, &img);
        // 16 tiles * (16 ld + 16 st) vector mem ops, zero scalar loads
        assert_eq!(c.mix.get(crate::neon::InstrClass::SimdLoad), 16 * 16);
        assert_eq!(c.mix.get(crate::neon::InstrClass::ScalarLoad), 0);
    }

    #[test]
    fn u16_tiled_transpose_uses_8x8_tiles() {
        // 64x64 u16 → (64/8)^2 = 64 tiles × 8 loads = 512 vector loads
        let img = synth::noise_u16(64, 64, 9);
        let mut c = Counting::new();
        let _ = transpose_image_u16(&mut c, &img);
        assert_eq!(c.mix.get(crate::neon::InstrClass::SimdLoad), 64 * 8);
        assert_eq!(c.mix.get(crate::neon::InstrClass::SimdStore), 64 * 8);
        assert_eq!(c.mix.get(crate::neon::InstrClass::ScalarLoad), 0);
    }

    #[test]
    fn edges_fall_back_to_scalar() {
        let img = synth::noise(18, 18, 10);
        let mut c = Counting::new();
        let got = transpose_image(&mut c, &img);
        assert!(got.same_pixels(&img.transposed()));
        // 1 NEON tile + (18*18 - 256) scalar edge pixels
        assert_eq!(c.mix.get(crate::neon::InstrClass::ScalarLoad), (18 * 18 - 256) as u64);
    }

    #[test]
    fn u16_edges_fall_back_to_scalar() {
        let img = synth::noise_u16(10, 10, 10);
        let mut c = Counting::new();
        let got = transpose_image_u16(&mut c, &img);
        assert!(got.same_pixels(&img.transposed()));
        // 1 NEON 8x8 tile + (10*10 - 64) scalar edge pixels
        assert_eq!(c.mix.get(crate::neon::InstrClass::ScalarLoad), (10 * 10 - 64) as u64);
    }
}
