//! `neon-morph` — CLI for the morphology filtering stack.
//!
//! Subcommands:
//!
//! * `filter`    — apply one operation to a PGM image (native or XLA).
//! * `bench`     — regenerate the paper's evaluation artifacts
//!   (`table1`, `fig3`, `fig4`, `e2e`, or `all`).
//! * `serve`     — drive the coordinator with a synthetic request load
//!   and report throughput/latency.
//! * `calibrate` — re-derive the §5.3 crossover thresholds from the
//!   instruction mixes + cost model.
//! * `demo`      — generate a document image, clean it with morphology,
//!   write before/after PGMs.
//! * `info`      — artifact manifest + runtime platform summary.
//!
//! Argument parsing is hand-rolled (`--key value` pairs) because the
//! offline build has no clap; see `Args`.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::process::ExitCode;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use neon_morph::bench_harness::{self, e2e, fig3, fig4, gate, rle, scaling, serve, table1};
use neon_morph::coordinator::{BackendChoice, Coordinator, CoordinatorConfig};
use neon_morph::costmodel::CostModel;
use neon_morph::image::{read_pgm, synth, write_pgm};
use neon_morph::morphology::{
    self, hybrid, Border, FilterSpec, HybridThresholds, MorphConfig, Parallelism, PassMethod,
    Representation, Roi, VerticalStrategy,
};
use neon_morph::neon::Native;
use neon_morph::runtime::{Manifest, XlaRuntime};
use neon_morph::util::json;

/// Minimal `--key value` / `--flag` argument map.
struct Args {
    positional: Vec<String>,
    named: BTreeMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut positional = Vec::new();
        let mut named = BTreeMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    named.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    named.insert(key.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    named.insert(key.to_string(), "true".to_string());
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Args { positional, named })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.named.get(key).map(String::as_str)
    }

    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} must be an integer")),
        }
    }

    fn flag(&self, key: &str) -> bool {
        self.get(key).is_some_and(|v| v != "false")
    }
}

const USAGE: &str = "\
neon-morph — fast separable morphological filtering (Limonova et al., CS.DC 2020)

USAGE:
    neon-morph <COMMAND> [OPTIONS]

COMMANDS:
    filter     --input in.pgm --output out.pgm [--op erode] [--wx 5] [--wy 5]
               [--backend auto|native|xla] [--method hybrid|linear|vhgw]
               [--vertical direct|transpose] [--border identity|replicate]
               [--no-simd] [--parallel auto|off|N] [--artifacts DIR]
               [--roi Y,X,H,W] [--repr dense|rle|auto] [--marker seed.pgm]
               --op takes any op or comma-chain of ops:
                 erode dilate opening closing gradient tophat blackhat
                 transpose (alone; ignores --wx/--wy, output is WxH)
                 reconstruct (alone; needs --marker — the input image is
                 the geodesic mask, the marker the seed; native only)
                 e.g. --op opening,gradient runs the ops left to right
               --repr picks the engine for 0/255 sources: rle runs the
                 interval engine, auto prices rle vs dense per request
                 (gray sources always run dense)
               --roi composes with EVERY op/chain (not just erode/dilate):
                 computes exactly crop(chain(full), roi) from a haloed
                 block on the native engine (rejects --backend xla);
                 output is HxW.  One FilterSpec -> FilterPlan drives the
                 whole command; see `morphology::plan`.
    bench      <table1|fig3|fig3u16|fig4|e2e|scaling|all> [--quick] [--tsv] [--iters N]
               scaling: [--max-workers 16] [--host]
    bench      smoke --out DIR [--update-baselines] [--baselines DIR]
               deterministic sweeps -> BENCH_{fig3,fig4,table1,scaling,serve,rle}.json
               (serve: streamed coordinator workload, plan-resolutions-
               per-request headline — count-exact; rle: modeled sparse
               speedup + crossover density + live reconstruction sweeps)
    bench      gate [--out DIR] [--baselines DIR]
               fail if headline ratios drift >10% from the committed baselines
    serve      [--requests 256] [--workers 4] [--window 7]
               [--backend native|xla|auto] [--artifacts DIR]
               native serving streams requests (SubmitStream) and
               reports plan-cache traffic alongside latency
    calibrate  [--max-window 121]
    demo       [--outdir /tmp] [--height 600] [--width 800]
    info       [--artifacts DIR]
";

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match run(&argv) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn run(argv: &[String]) -> Result<()> {
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" {
        print!("{USAGE}");
        return Ok(());
    }
    let cmd = argv[0].as_str();
    let args = Args::parse(&argv[1..])?;
    match cmd {
        "filter" => cmd_filter(&args),
        "bench" => cmd_bench(&args),
        "serve" => cmd_serve(&args),
        "calibrate" => cmd_calibrate(&args),
        "demo" => cmd_demo(&args),
        "info" => cmd_info(&args),
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

fn parse_morph_config(args: &Args) -> Result<MorphConfig> {
    let method = match args.get("method").unwrap_or("hybrid") {
        "hybrid" => PassMethod::Hybrid,
        "linear" => PassMethod::Linear,
        "vhgw" => PassMethod::Vhgw,
        m => bail!("unknown --method {m:?}"),
    };
    let vertical = match args.get("vertical").unwrap_or("direct") {
        "transpose" => VerticalStrategy::Transpose,
        "direct" => VerticalStrategy::Direct,
        v => bail!("unknown --vertical {v:?}"),
    };
    let border = match args.get("border").unwrap_or("identity") {
        "identity" => Border::Identity,
        "replicate" => Border::Replicate,
        b => bail!("unknown --border {b:?}"),
    };
    let parallelism = match args.get("parallel").unwrap_or("auto") {
        "auto" => Parallelism::Auto,
        "off" => Parallelism::Sequential,
        n => Parallelism::Fixed(
            n.parse()
                .with_context(|| format!("--parallel must be auto|off|N, got {n:?}"))?,
        ),
    };
    let representation: Representation = args
        .get("repr")
        .unwrap_or("dense")
        .parse()
        .map_err(|e| anyhow!("--repr: {e}"))?;
    Ok(MorphConfig {
        method,
        vertical,
        simd: !args.flag("no-simd"),
        border,
        thresholds: HybridThresholds::paper(),
        parallelism,
        representation,
    })
}

fn parse_backend(args: &Args) -> Result<BackendChoice> {
    Ok(match args.get("backend").unwrap_or("auto") {
        "auto" => BackendChoice::Auto,
        "native" => BackendChoice::NativeOnly,
        "xla" => BackendChoice::XlaOnly,
        b => bail!("unknown --backend {b:?}"),
    })
}

fn cmd_filter(args: &Args) -> Result<()> {
    let input = args.get("input").ok_or_else(|| anyhow!("--input required"))?;
    let output = args.get("output").ok_or_else(|| anyhow!("--output required"))?;
    let op_str = args.get("op").unwrap_or("erode").to_string();
    let w_x = args.get_usize("wx", 5)?;
    let w_y = args.get_usize("wy", 5)?;
    let backend = parse_backend(args)?;
    let morph = parse_morph_config(args)?;
    let artifacts = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));

    // one spec describes the whole command: op chain + window + config
    // (+ optional ROI); the coordinator plans it once and executes
    let ops = FilterSpec::parse_ops(&op_str).map_err(|e| anyhow!("--op: {e}"))?;
    let mut spec = FilterSpec {
        ops,
        w_x,
        w_y,
        config: morph,
        roi: None,
    };

    let img = Arc::new(read_pgm(input).with_context(|| format!("reading {input}"))?);
    let (ih, iw) = (img.height(), img.width());

    // --roi: region-of-interest filtering on the native path — valid
    // for every op and chain (the plan computes crop(chain(full), roi)
    // from a haloed block; only the block is ever read)
    if let Some(roi_str) = args.get("roi") {
        if backend == BackendChoice::XlaOnly {
            bail!("--roi runs on the native engine and cannot honour --backend xla");
        }
        let roi: Roi = roi_str.parse().map_err(|e| anyhow!("--roi: {e}"))?;
        spec = spec.with_roi(roi);
    }
    spec.validate(ih, iw)
        .map_err(|e| anyhow!("{e} (image {ih}x{iw})"))?;

    // --marker: the reconstruction seed (the input image is the
    // geodesic mask).  Pairing is validated at pipeline ingress, so a
    // marker on a non-reconstruct op (or a markerless reconstruct)
    // comes back as a request error, not a crash.
    let marker = match args.get("marker") {
        Some(path) => {
            if backend == BackendChoice::XlaOnly {
                bail!("reconstruct runs on the native engine and cannot honour --backend xla");
            }
            Some(Arc::new(
                read_pgm(path).with_context(|| format!("reading marker {path}"))?,
            ))
        }
        None => None,
    };

    let coord = Coordinator::start(CoordinatorConfig {
        workers: 1,
        backend,
        artifact_dir: Some(artifacts),
        morph,
        ..CoordinatorConfig::default()
    })?;
    let resp = match marker {
        Some(mk) => coord.filter_spec_with_marker(spec, img, mk)?,
        None => coord.filter_spec(spec, img)?,
    };
    let out = resp.result?.into_u8()?;
    write_pgm(&out, output).with_context(|| format!("writing {output}"))?;
    match spec.roi {
        Some(roi) => println!(
            "{} roi {},{},{}x{} of {ih}x{iw} SE={}x{} via {} in {:.2} ms -> {}",
            op_str,
            roi.y,
            roi.x,
            roi.height,
            roi.width,
            w_x,
            w_y,
            resp.backend,
            resp.exec_ns as f64 / 1e6,
            output
        ),
        None => println!(
            "{} {}x{} SE={}x{} via {} in {:.2} ms -> {}",
            op_str,
            out.height(),
            out.width(),
            w_x,
            w_y,
            resp.backend,
            resp.exec_ns as f64 / 1e6,
            output
        ),
    }
    coord.shutdown();
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("all");
    if !["table1", "fig3", "fig3u16", "fig4", "e2e", "scaling", "smoke", "gate", "all"]
        .contains(&which)
    {
        bail!("unknown bench {which:?} (want table1|fig3|fig3u16|fig4|e2e|scaling|smoke|gate|all)");
    }
    if which == "smoke" {
        return cmd_bench_smoke(args);
    }
    if which == "gate" {
        return cmd_bench_gate(args);
    }
    let quick = args.flag("quick");
    let tsv = args.flag("tsv");
    let iters = args.get_usize("iters", if quick { 2 } else { 5 })?;
    let model = CostModel::exynos5422();
    let windows = if quick {
        bench_harness::window_sweep_quick()
    } else {
        bench_harness::window_sweep()
    };

    if which == "table1" || which == "all" {
        let rows = table1::run(&model);
        print!("{}", table1::render(&rows).to_markdown());
        println!();
    }
    if which == "fig3" || which == "all" {
        let s = fig3::run(&model, &windows, iters);
        let t_model = fig3::render(
            "Figure 3 — horizontal pass erosion, cost model (Exynos 5422, ns)",
            &s,
            "model",
        );
        let t_host = fig3::render("Figure 3 — horizontal pass erosion, host wall-clock (ns)", &s, "host");
        if tsv {
            print!("{}", t_model.to_tsv());
        } else {
            print!("{}", t_model.to_markdown());
            println!();
            print!("{}", t_host.to_markdown());
        }
        println!(
            "crossover w_y0: model={} host={} (paper: 69)\n",
            s.crossover_model, s.crossover_host
        );
    }
    if which == "fig3u16" || which == "all" {
        let s = fig3::run_u16(&model, &windows, iters);
        let t_model = fig3::render(
            "Figure 3 (u16) — horizontal pass erosion on 800x600 u16, cost model (ns)",
            &s,
            "model",
        );
        let t_host = fig3::render(
            "Figure 3 (u16) — horizontal pass erosion on 800x600 u16, host wall-clock (ns)",
            &s,
            "host",
        );
        if tsv {
            print!("{}", t_model.to_tsv());
        } else {
            print!("{}", t_model.to_markdown());
            println!();
            print!("{}", t_host.to_markdown());
        }
        println!(
            "u16 crossover w_y0: model={} host={} (8 lanes/op vs 16 at u8)\n",
            s.crossover_model, s.crossover_host
        );
    }
    if which == "fig4" || which == "all" {
        let s = fig4::run(&model, &windows, iters);
        let t_model = fig4::render(
            "Figure 4 — vertical pass erosion, cost model (Exynos 5422, ns)",
            &s,
            "model",
        );
        let t_host = fig4::render("Figure 4 — vertical pass erosion, host wall-clock (ns)", &s, "host");
        if tsv {
            print!("{}", t_model.to_tsv());
        } else {
            print!("{}", t_model.to_markdown());
            println!();
            print!("{}", t_host.to_markdown());
        }
        println!(
            "crossover w_x0: model={} host={} (paper: 59)\n",
            s.crossover_model, s.crossover_host
        );
    }
    if which == "scaling" || which == "all" {
        let max_workers = args.get_usize("max-workers", 16)?;
        let host_iters = if args.flag("host") { iters } else { 0 };
        let s = scaling::run(
            &model,
            synth::PAPER_HEIGHT,
            synth::PAPER_WIDTH,
            scaling::SCALING_WINDOW,
            max_workers,
            host_iters,
        );
        let t = scaling::render(&s);
        if tsv {
            print!("{}", t.to_tsv());
        } else {
            print!("{}", t.to_markdown());
        }
        println!(
            "modeled saturation: P={} (speedup {:.2}x, memory-bandwidth ceiling {:.2}x)\n",
            s.saturation,
            s.speedup_at(s.saturation),
            s.ceiling
        );
    }
    if which == "e2e" || which == "all" {
        let ws = if quick { vec![7, 15] } else { vec![3, 7, 15, 31, 61] };
        let results = e2e::run(&model, &ws, iters);
        print!("{}", e2e::render(&results).to_markdown());
        println!();
        let s = e2e::serve_native(if quick { 32 } else { 256 }, 4, 7)?;
        println!(
            "serving: {} reqs, {} workers -> {:.1} req/s, p50 {:.1} ms, p99 {:.1} ms, mean batch {:.2}",
            s.requests,
            s.workers,
            s.throughput_rps,
            s.p50_us / 1e3,
            s.p99_us / 1e3,
            s.mean_batch
        );
    }
    Ok(())
}

/// Default location of the committed perf baselines, relative to the
/// repository root (where CI invokes the binary).
const BASELINE_DIR: &str = "rust/benches/baselines";

/// `bench smoke`: run the deterministic cost-model sweeps and write the
/// machine-readable `BENCH_*.json` reports CI uploads and gates.
fn cmd_bench_smoke(args: &Args) -> Result<()> {
    let out_dir = PathBuf::from(args.get("out").unwrap_or("bench_out"));
    std::fs::create_dir_all(&out_dir)
        .with_context(|| format!("creating {}", out_dir.display()))?;
    let model = CostModel::exynos5422();

    let fig3_sweep = fig3::run(&model, &scaling::SMOKE_WINDOWS, 0);
    let fig3_report = scaling::fig3_json(&fig3_sweep);
    let fig3u16_sweep = fig3::run_u16(&model, &scaling::SMOKE_WINDOWS, 0);
    let fig3u16_report = scaling::fig3u16_json(&fig3u16_sweep);
    let fig4_sweep = fig4::run(&model, &scaling::SMOKE_WINDOWS, 0);
    let fig4_report = scaling::fig4_json(&fig4_sweep);
    let table1_rows = table1::run_model(&model);
    let table1_report = scaling::table1_json(&table1_rows);
    let scaling_sweep = scaling::run(
        &model,
        synth::PAPER_HEIGHT,
        synth::PAPER_WIDTH,
        scaling::SCALING_WINDOW,
        16,
        0,
    );
    let scaling_report = scaling::to_json(&scaling_sweep);
    // serving smoke: count-exact plan-cache headlines of a streamed
    // coordinator workload (1 worker — resolutions are deterministic)
    // plus the model-priced fused-batch throughput and the saturation
    // arithmetic (budget-admitted bursts), backed by a live
    // saturating-producer run (reported, not gated)
    let serve_smoke = serve::run_smoke()?;
    let serve_fused = serve::fused_model(&model);
    let serve_sat = serve::saturate_model(&model, &serve_fused);
    let serve_live = serve::run_saturated()?;
    let serve_report = serve::to_json(&serve_smoke, &serve_fused, &serve_sat, &serve_live);
    // scenario-engine smoke: modeled RLE-vs-dense ratios plus the
    // deterministic sweep count of a live reconstruction plan run
    let rle_smoke = rle::run_smoke(&model)?;
    let rle_report = rle::to_json(&rle_smoke);
    // banded-transpose smoke: closed-form tile-network throughput and
    // banded/in-sandwich speedups (loop-exact vs the counted censuses)
    let transpose_cases = bench_harness::transpose::run_model(&model);
    let transpose_report = bench_harness::transpose::to_json(&transpose_cases);

    let reports = [
        ("BENCH_fig3.json", &fig3_report),
        ("BENCH_fig3_u16.json", &fig3u16_report),
        ("BENCH_fig4.json", &fig4_report),
        ("BENCH_table1.json", &table1_report),
        ("BENCH_scaling.json", &scaling_report),
        ("BENCH_serve.json", &serve_report),
        ("BENCH_rle.json", &rle_report),
        ("BENCH_transpose.json", &transpose_report),
    ];
    for (name, report) in reports {
        let path = out_dir.join(name);
        std::fs::write(&path, json::write(report))
            .with_context(|| format!("writing {}", path.display()))?;
        println!("wrote {}", path.display());
    }
    print!(
        "{}",
        fig3::render("Figure 3 smoke (model, ns)", &fig3_sweep, "model").to_markdown()
    );
    println!();
    print!(
        "{}",
        fig3::render("Figure 3 u16 smoke (model, ns)", &fig3u16_sweep, "model").to_markdown()
    );
    println!();
    print!(
        "{}",
        fig4::render("Figure 4 smoke (model, ns)", &fig4_sweep, "model").to_markdown()
    );
    println!();
    print!("{}", table1::render(&table1_rows).to_markdown());
    println!();
    print!("{}", scaling::render(&scaling_sweep).to_markdown());
    println!();
    print!("{}", bench_harness::transpose::render(&transpose_cases).to_markdown());
    println!(
        "serve smoke: {} requests -> {} plan resolutions, {} hits \
         ({:.4} resolutions/request); {} fused batches / {} fused requests",
        serve_smoke.requests,
        serve_smoke.plan_resolutions,
        serve_smoke.plan_hits,
        serve_smoke.plan_resolutions as f64 / serve_smoke.requests as f64,
        serve_smoke.fused_batches,
        serve_smoke.fused_requests,
    );
    println!(
        "fused-batch model ({} workers): {:.0}/{:.0}/{:.0} images/s at batch 1/8/64, \
         x{:.2} fused:sequential at 64",
        serve::SERVE_FUSED_WORKERS,
        serve_fused.images_per_sec[0],
        serve_fused.images_per_sec[1],
        serve_fused.images_per_sec[2],
        serve_fused.speedup_at_64,
    );
    println!(
        "saturation model (budget {}/key, {}-req bursts): {} accepted / {} shed, \
         tail {:.2} ms; live run: {} accepted / {} shed / {} replied, \
         stage peaks {:?}",
        serve::SATURATE_BUDGET,
        serve::SATURATE_BURST,
        serve_sat.accepted,
        serve_sat.shed,
        serve_sat.tail_ms,
        serve_live.accepted,
        serve_live.shed,
        serve_live.replied,
        serve_live.stage_peak,
    );
    println!(
        "rle smoke: x{:.2} modeled speedup at {:.0}% density (crossover {:.3}); \
         reconstruction reached its fixpoint in {} sweeps ({} px foreground)",
        rle_smoke.speedup_sparse5pct,
        100.0 * rle::RLE_SPARSE_DENSITY,
        rle_smoke.crossover_density,
        rle_smoke.reconstruct_sweeps,
        rle_smoke.reconstruct_foreground,
    );

    if args.flag("update-baselines") {
        let base_dir = PathBuf::from(args.get("baselines").unwrap_or(BASELINE_DIR));
        std::fs::create_dir_all(&base_dir)
            .with_context(|| format!("creating {}", base_dir.display()))?;
        for (name, report) in reports {
            let path = base_dir.join(name);
            std::fs::write(&path, json::write(&gate::headline_subset(report)))
                .with_context(|| format!("writing {}", path.display()))?;
            println!("updated baseline {}", path.display());
        }
    }
    Ok(())
}

/// `bench gate`: compare the measured reports against the committed
/// baselines; non-zero exit on any >10% headline drift.
fn cmd_bench_gate(args: &Args) -> Result<()> {
    let out_dir = PathBuf::from(args.get("out").unwrap_or("bench_out"));
    let base_dir = PathBuf::from(args.get("baselines").unwrap_or(BASELINE_DIR));
    let mut total_failures = 0usize;
    let mut checked = 0usize;
    for name in [
        "BENCH_fig3.json",
        "BENCH_fig3_u16.json",
        "BENCH_fig4.json",
        "BENCH_table1.json",
        "BENCH_scaling.json",
        "BENCH_serve.json",
        "BENCH_rle.json",
        "BENCH_transpose.json",
    ] {
        let base_path = base_dir.join(name);
        let meas_path = out_dir.join(name);
        let base_text = std::fs::read_to_string(&base_path)
            .with_context(|| format!("reading baseline {}", base_path.display()))?;
        let meas_text = std::fs::read_to_string(&meas_path).with_context(|| {
            format!("reading measurement {} (run `bench smoke` first)", meas_path.display())
        })?;
        let base = json::parse(&base_text)
            .map_err(|e| anyhow!("{}: {e}", base_path.display()))?;
        let meas = json::parse(&meas_text)
            .map_err(|e| anyhow!("{}: {e}", meas_path.display()))?;
        let failures = gate::compare(&base, &meas, gate::GATE_TOLERANCE);
        checked += 1;
        if failures.is_empty() {
            println!("PASS {name}");
        } else {
            total_failures += failures.len();
            println!("FAIL {name}:");
            for f in &failures {
                println!("  {f}");
            }
        }
    }
    if total_failures > 0 {
        bail!(
            "perf gate failed: {total_failures} headline ratio(s) drifted beyond {:.0}% \
             (regenerate with `bench smoke --update-baselines` if intentional)",
            gate::GATE_TOLERANCE * 100.0
        );
    }
    println!("perf gate passed ({checked} reports within {:.0}%)", gate::GATE_TOLERANCE * 100.0);
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let requests = args.get_usize("requests", 256)?;
    let workers = args.get_usize("workers", 4)?;
    let window = args.get_usize("window", 7)?;
    let backend = parse_backend(args)?;
    let artifacts = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));

    if backend == BackendChoice::NativeOnly {
        // native serving runs the STREAMING submit path: one
        // SubmitStream producer, plan-pinned workers draining same-key
        // runs (see `examples/streaming_serve.rs` for the API)
        let s = e2e::serve_native(requests, workers, window)?;
        println!(
            "completed {} requests on {} workers in {:.2}s: {:.1} req/s, \
             p50 {:.2} ms, p99 {:.2} ms, mean batch {:.2}, shed {}, \
             plans resolved/hit {}/{} ({:.4} resolutions/req), \
             stage peaks [in/res/exec/reply] {:?}",
            s.requests, s.workers, s.wall_s, s.throughput_rps,
            s.p50_us / 1e3, s.p99_us / 1e3, s.mean_batch, s.shed,
            s.plan_resolutions, s.plan_hits, s.plan_resolutions_per_request(),
            s.stage_peak
        );
        return Ok(());
    }

    // XLA/Auto path: serve the artifact shapes
    let coord = Coordinator::start(CoordinatorConfig {
        workers,
        queue_capacity: requests + 8,
        backend,
        artifact_dir: Some(artifacts),
        precompile: true,
        ..CoordinatorConfig::default()
    })?;
    let manifest = coord
        .manifest()
        .ok_or_else(|| anyhow!("no artifacts found — run `make artifacts`"))?;
    let metas: Vec<_> = manifest
        .ops_for_shape(256, 256)
        .into_iter()
        .filter(|m| m.kind == "morphology")
        .cloned()
        .collect();
    if metas.is_empty() {
        bail!("no 256x256 artifacts in manifest");
    }
    let img = Arc::new(synth::noise(256, 256, 1));
    let t0 = std::time::Instant::now();
    let tickets: Vec<_> = (0..requests)
        .map(|i| {
            let m = &metas[i % metas.len()];
            let op = m.op.parse().map_err(|e| anyhow!("manifest op: {e}"))?;
            coord.submit(FilterSpec::new(op, m.w_x, m.w_y), img.clone())
        })
        .collect::<Result<_>>()?;
    let mut xla_count = 0u64;
    for t in tickets {
        let r = t.wait()?;
        r.result?;
        if r.backend == "xla-pjrt" {
            xla_count += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = coord.metrics();
    println!(
        "completed {} requests ({} on xla-pjrt) on {} workers in {:.2}s: {:.1} req/s\n{}",
        snap.completed,
        xla_count,
        workers,
        wall,
        snap.completed as f64 / wall,
        snap
    );
    coord.shutdown();
    Ok(())
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    let max_window = args.get_usize("max-window", 121)?;
    let model = CostModel::exynos5422();
    let probe = synth::paper_image(7);
    let t = hybrid::calibrate_thresholds(&model, &probe, max_window);
    println!(
        "calibrated crossovers on 800x600 u8 (cost model):\n\
         w_y0 = {} (paper: {})\n\
         w_x0 = {} (paper: {})",
        t.wy0,
        morphology::PAPER_WY0,
        t.wx0,
        morphology::PAPER_WX0
    );
    Ok(())
}

fn cmd_demo(args: &Args) -> Result<()> {
    let outdir = PathBuf::from(args.get("outdir").unwrap_or("/tmp"));
    let h = args.get_usize("height", 600)?;
    let w = args.get_usize("width", 800)?;
    std::fs::create_dir_all(&outdir)?;

    let doc = synth::document(h, w, 42);
    write_pgm(&doc, outdir.join("demo_input.pgm"))?;

    let b = &mut Native;
    let cfg = MorphConfig::default();
    let cleaned = morphology::closing(b, &doc, 3, 3, &cfg); // drop salt noise
    let opened = morphology::opening(b, &cleaned, 3, 3, &cfg); // drop pepper
    write_pgm(&opened, outdir.join("demo_cleaned.pgm"))?;
    let grad = morphology::gradient(b, &doc, 3, 3, &cfg);
    write_pgm(&grad, outdir.join("demo_gradient.pgm"))?;
    let lines = morphology::erode(&doc, 41, 1);
    write_pgm(&lines, outdir.join("demo_textlines.pgm"))?;

    println!(
        "wrote demo_input.pgm, demo_cleaned.pgm, demo_gradient.pgm, demo_textlines.pgm to {}",
        outdir.display()
    );
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    match Manifest::load(&dir) {
        Ok(m) => {
            println!("manifest: {} artifacts in {}", m.len(), dir.display());
            for name in m.names() {
                let a = m.get(name).unwrap();
                println!(
                    "  {:<28} {}x{} SE {}x{} [{}]",
                    a.name, a.height, a.width, a.w_x, a.w_y, a.kind
                );
            }
        }
        Err(e) => println!("no manifest: {e:#}"),
    }
    match XlaRuntime::new(&dir) {
        Ok(rt) => println!("pjrt platform: {}", rt.platform()),
        Err(e) => println!("pjrt unavailable: {e:#}"),
    }
    Ok(())
}
