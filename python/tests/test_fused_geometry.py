"""Independent verification of the fused multi-image band geometry used
by ``rust/src/morphology/parallel.rs`` (``split_fused_bands``).

A fused super-pass stacks a batch of ``n`` same-shape images into a
virtual ``n*h``-row image and splits bands across the *fused* extent, so
one fork-join serves the whole batch.  Correctness rests on two
geometric invariants this file mirrors and checks against brute-force
oracles:

1. **Tiling**: the fused bands cover ``[0, n*h)`` contiguously, and each
   band decomposes into per-image row segments that never cross an image
   seam.
2. **Seam fences**: each segment's halo is clamped to its *own* image
   (``halo`` against ``h``, not ``n*h``), so a window reduction never
   reads a neighboring image's rows — which is exactly why fused output
   is bit-identical to running each image alone.

Interior band cuts are aligned *image-locally* (``(cut % h) % align ==
0``), matching the rust snap ``g - (g % h) % align``: a cut landing on a
seam (``cut % h == 0``) is always legal regardless of alignment.
"""

import random

# ---- mirrors of rust/src/morphology/parallel.rs fused geometry ----------


def split_fused_bands(n, h, parts, align):
    align = max(align, 1)
    parts = max(parts, 1)
    total = n * h
    if total == 0:
        return []
    cuts = [0]
    for i in range(1, parts):
        g = i * total // parts
        snapped = g - (g % h) % align
        if snapped > cuts[-1]:
            cuts.append(snapped)
    cuts.append(total)
    out = []
    for a, b in zip(cuts, cuts[1:]):
        band = []
        pos = a
        while pos < b:
            img = pos // h
            lo = pos - img * h
            hi = min(b - img * h, h)
            band.append((img, (lo, hi)))
            pos = img * h + hi
        out.append(band)
    return out


def halo(band, wing, length):
    b0, b1 = band
    return (max(0, b0 - wing), min(b1 + wing, length))


# ---- oracle: per-image 1-D window reduction (identity padding) ----------


def rows_pass(img, window, ident, comb):
    wing = window // 2
    h = len(img)
    out = []
    for y in range(h):
        row = []
        for x in range(len(img[0])):
            acc = ident
            for k in range(y - wing, y + wing + 1):
                v = img[k][x] if 0 <= k < h else ident
                acc = comb(acc, v)
            row.append(acc)
        out.append(row)
    return out


def fused_banded_rows_pass(imgs, window, ident, comb, bands, align=1):
    """The rust fused strategy: for every per-image segment of every
    fused band, halo against its OWN image and run the sequential pass
    on the haloed slab."""
    n, h = len(imgs), len(imgs[0])
    outs = [[None] * h for _ in imgs]
    wing = window // 2
    for band in split_fused_bands(n, h, bands, align):
        for img_idx, seg in band:
            lo, hi = halo(seg, wing, h)  # seam fence: clamp to h, not n*h
            slab = imgs[img_idx][lo:hi]
            slab_out = rows_pass(slab, window, ident, comb)
            for y in range(seg[0], seg[1]):
                outs[img_idx][y] = slab_out[y - lo]
    return outs


# ---- structural tests ---------------------------------------------------


def test_fused_bands_tile_the_fused_extent():
    for n, h, parts, align in [
        (5, 13, 3, 1),
        (5, 13, 4, 8),
        (2, 7, 9, 1),
        (4, 1, 3, 1),   # 1-row images: every cut is a seam
        (1, 20, 4, 16),
        (8, 3, 5, 4),
    ]:
        plan = split_fused_bands(n, h, parts, align)
        flat = [(i, seg) for band in plan for (i, seg) in band]
        # contiguous cover of the fused [0, n*h) extent, in order
        pos = 0
        for img_idx, (lo, hi) in flat:
            assert 0 <= lo < hi <= h
            assert img_idx * h + lo == pos, "segments must tile the fused extent"
            pos = img_idx * h + hi
        assert pos == n * h
        # no segment crosses a seam (by construction hi <= h), and each
        # image's segments are contiguous from 0 to h
        per_img = {}
        for img_idx, seg in flat:
            per_img.setdefault(img_idx, []).append(seg)
        assert sorted(per_img) == list(range(n))
        for segs in per_img.values():
            assert segs[0][0] == 0 and segs[-1][1] == h
            for (a0, a1), (b0, b1) in zip(segs, segs[1:]):
                assert a1 == b0
        # interior cuts are image-locally aligned OR on a seam
        cuts = set()
        pos = 0
        for band in plan:
            if pos != 0:
                cuts.add(pos)
            pos += sum(hi - lo for _, (lo, hi) in band)
        for cut in cuts:
            assert (cut % h) % align == 0, f"cut {cut} not image-locally aligned"


def test_degenerate_shapes_are_empty():
    assert split_fused_bands(0, 10, 3, 1) == []
    assert split_fused_bands(3, 0, 3, 1) == []


def test_single_band_is_the_whole_stack():
    plan = split_fused_bands(3, 5, 1, 1)
    assert len(plan) == 1
    assert plan[0] == [(0, (0, 5)), (1, (0, 5)), (2, (0, 5))]


# ---- the fence theorem --------------------------------------------------


def test_fused_banding_matches_per_image_randomized():
    rng = random.Random(0xF5ED)
    for case in range(200):
        n = rng.randint(1, 6)
        h = rng.randint(1, 12)
        w = rng.randint(1, 5)
        window = rng.choice([1, 3, 5, 9])
        bands = rng.randint(1, n * h + 3)
        align = rng.choice([1, 2, 8])
        imgs = [
            [[rng.randint(0, 255) for _ in range(w)] for _ in range(h)]
            for _ in range(n)
        ]
        for ident, comb in [(255, min), (0, max)]:
            want = [rows_pass(img, window, ident, comb) for img in imgs]
            got = fused_banded_rows_pass(imgs, window, ident, comb, bands, align)
            assert got == want, (
                f"case {case}: n={n} h={h} w={w} window={window} "
                f"bands={bands} align={align} ident={ident} diverged"
            )


def test_one_row_images_never_leak_across_seams():
    # h=1 with a tall window: the fence is all that separates neighbors.
    # Without per-image clamping, image i's output would absorb rows of
    # images i-1 / i+1; with it, each row reduces over itself only.
    rng = random.Random(1)
    imgs = [[[rng.randint(0, 255) for _ in range(4)]] for _ in range(8)]
    for bands in (1, 3, 8, 11):
        got = fused_banded_rows_pass(imgs, 9, 255, min, bands)
        want = [rows_pass(img, 9, 255, min) for img in imgs]
        assert got == want
