"""Independent verification of the band/halo geometry used by
``rust/src/morphology/parallel.rs``.

The rust banded passes copy a haloed row slab, run the *unchanged*
sequential pass on it, and stitch the core rows back.  Their
bit-identity claim reduces to a pure geometry theorem: for a 1-D
window reduction with identity padding, computing rows ``[b0, b1)``
on the sub-image of rows ``[b0 - wing, b1 + wing) ∩ [0, h)`` yields
exactly the full-image result.  This file mirrors ``split_bands`` /
``split_bands_aligned`` / ``halo`` and checks the theorem against a
brute-force oracle over randomized shapes, windows and band counts —
including the degenerate cases the rust property tests pin (bands >
rows, window > band height, single-row images).
"""

import random

# ---- mirrors of rust/src/morphology/parallel.rs geometry ----------------


def split_bands_aligned(length, parts, align):
    align = max(align, 1)
    parts = max(parts, 1)
    if length == 0:
        return []
    out = []
    start = 0
    for i in range(1, parts + 1):
        end = i * length // parts
        if i != parts:
            end = end // align * align
        else:
            end = length
        if end > start:
            out.append((start, end))
            start = end
    return out


def split_bands(length, parts):
    return split_bands_aligned(length, parts, 1)


def halo(band, wing, length):
    b0, b1 = band
    return (max(0, b0 - wing), min(b1 + wing, length))


# ---- oracle: 1-D window reduction over rows with identity padding -------


def rows_pass(img, window, ident, comb):
    """out[y][x] = comb over rows [y-wing, y+wing] ∩ image (identity pad)."""
    wing = window // 2
    h = len(img)
    out = []
    for y in range(h):
        row = []
        for x in range(len(img[0])):
            acc = ident
            for k in range(y - wing, y + wing + 1):
                v = img[k][x] if 0 <= k < h else ident
                acc = comb(acc, v)
            row.append(acc)
        out.append(row)
    return out


def banded_rows_pass(img, window, ident, comb, bands):
    """The rust strategy: haloed slab -> sequential pass -> core rows."""
    h = len(img)
    wing = window // 2
    out = [None] * h
    for band in split_bands(h, bands):
        lo, hi = halo(band, wing, h)
        slab = img[lo:hi]
        slab_out = rows_pass(slab, window, ident, comb)
        for y in range(band[0], band[1]):
            out[y] = slab_out[y - lo]
    return out


# ---- tests --------------------------------------------------------------


def test_split_bands_tile_and_cover():
    for length, parts in [(10, 3), (1, 4), (7, 7), (7, 20), (600, 8), (16, 1), (0, 3)]:
        plan = split_bands(length, parts)
        if length == 0:
            assert plan == []
            continue
        assert plan[0][0] == 0
        assert plan[-1][1] == length
        for (a0, a1), (b0, b1) in zip(plan, plan[1:]):
            assert a1 == b0, "bands must tile contiguously"
        assert all(b1 > b0 for b0, b1 in plan)
        assert len(plan) <= parts


def test_aligned_bands_interior_boundaries():
    plan = split_bands_aligned(100, 3, 16)
    assert plan[-1][1] == 100
    for b0, b1 in plan[:-1]:
        assert b1 % 16 == 0
    assert split_bands_aligned(10, 4, 16) == [(0, 10)]


def test_halo_clamps():
    assert halo((0, 10), 3, 100) == (0, 13)
    assert halo((50, 60), 3, 100) == (47, 63)
    assert halo((90, 100), 3, 100) == (87, 100)
    assert halo((0, 5), 7, 5) == (0, 5)


def test_banding_theorem_randomized():
    rng = random.Random(0xBA2D)
    for case in range(200):
        h = rng.randint(1, 24)
        w = rng.randint(1, 6)
        window = rng.choice([1, 3, 5, 9, 15])
        bands = rng.randint(1, h + 4)
        img = [[rng.randint(0, 255) for _ in range(w)] for _ in range(h)]
        for ident, comb in [(255, min), (0, max)]:
            want = rows_pass(img, window, ident, comb)
            got = banded_rows_pass(img, window, ident, comb, bands)
            assert got == want, (
                f"case {case}: h={h} w={w} window={window} bands={bands} "
                f"ident={ident} diverged"
            )


def test_window_larger_than_band_height():
    rng = random.Random(7)
    img = [[rng.randint(0, 255) for _ in range(4)] for _ in range(9)]
    # 9 bands of one row each, window spanning 15 rows
    want = rows_pass(img, 15, 255, min)
    got = banded_rows_pass(img, 15, 255, min, 9)
    assert got == want


def test_u16_range_identity_values():
    rng = random.Random(16)
    img = [[rng.randint(0, 65535) for _ in range(3)] for _ in range(11)]
    want = rows_pass(img, 5, 65535, min)
    got = banded_rows_pass(img, 5, 65535, min, 4)
    assert got == want
