"""Independent verification of the banded §4 tile-transpose geometry
used by ``rust/src/morphology/parallel.rs`` / ``rust/src/transpose``.

``transpose_image_banded_into`` splits the *source* rows into
tile-aligned bands and hands band ``[y0, y1)`` a destination **column
stripe**: columns ``[y0, y1)`` of every row of the ``w × h`` transposed
image (an ``ImageViewMut::split_cols_mut`` stripe).  Its bit-identity
claim reduces to pure geometry:

* the stripe plans are pairwise disjoint and together cover every
  destination cell exactly once (so concurrent band jobs never alias),
* interior stripe boundaries are LANES-aligned, so no §4 tile straddles
  a boundary and the tiled interior of each band reproduces the
  whole-image driver's tile grid exactly, and
* each band's tiled/scalar row partition (``t0``/``t1`` in
  ``transpose_band_into``) covers the band's source rows exactly once.

This file mirrors that geometry and checks it against brute-force
oracles over randomized shapes, band counts and source strides.  It
runs without the rust toolchain (tier-1).
"""

import random

# ---- mirrors of the rust geometry ---------------------------------------


def split_bands_aligned(length, parts, align):
    """Mirror of ``parallel::split_bands_aligned``."""
    align = max(align, 1)
    parts = max(parts, 1)
    if length == 0:
        return []
    out = []
    start = 0
    for i in range(1, parts + 1):
        end = i * length // parts
        if i != parts:
            end = end // align * align
        else:
            end = length
        if end > start:
            out.append((start, end))
            start = end
    return out


def tile_partition(band, tile):
    """Mirror of ``transpose_band_into``'s row split: rows ``[t0, t1)``
    run the tile network, ``[y0, t0) ∪ [t1, y1)`` fall back to scalar."""
    y0, y1 = band
    t0 = min((y0 + tile - 1) // tile * tile, y1)
    t1 = t0 + (y1 - t0) // tile * tile
    return t0, t1


def banded_transpose(img, h, w, bands, lanes, stride=None):
    """Simulate the banded driver on a flat source buffer: each band
    writes only its own column stripe of the ``w × h`` destination,
    through the band kernel's tiled/scalar row partition.  Returns the
    flat destination plus a per-cell write count (aliasing check)."""
    stride = w if stride is None else stride
    dst = [None] * (w * h)
    writes = [0] * (w * h)
    for y0, y1 in split_bands_aligned(h, bands, lanes):
        t0, t1 = tile_partition((y0, y1), lanes)
        tw = w - w % lanes
        # tiled interior rows, then the scalar boundary rows and the
        # right-edge columns — same traversal as the rust kernel
        spans = [(t0, t1, 0, tw), (y0, t0, 0, tw), (t1, y1, 0, tw), (y0, y1, tw, w)]
        for ya, yb, xa, xb in spans:
            for y in range(ya, yb):
                for x in range(xa, xb):
                    dst[x * h + y] = img[y * stride + x]
                    writes[x * h + y] += 1
    return dst, writes


def naive_transpose(img, h, w, stride=None):
    stride = w if stride is None else stride
    return [img[y * stride + x] for x in range(w) for y in range(h)]


# ---- tests --------------------------------------------------------------


def test_stripe_plans_disjoint_cover_aligned():
    rng = random.Random(0x57121)
    for _ in range(300):
        h = rng.randint(0, 70)
        bands = rng.randint(1, h + 6)
        lanes = rng.choice([8, 16])
        plan = split_bands_aligned(h, bands, lanes)
        if h == 0:
            assert plan == []
            continue
        # contiguous cover of the destination columns [0, h)
        assert plan[0][0] == 0 and plan[-1][1] == h
        for (_, a1), (b0, _) in zip(plan, plan[1:]):
            assert a1 == b0, "stripes must tile contiguously"
        assert all(b1 > b0 for b0, b1 in plan), "empty stripes are dropped"
        # interior boundaries tile-aligned: no §4 tile straddles a cut
        for b0, b1 in plan[:-1]:
            assert b1 % lanes == 0
        assert len(plan) <= bands


def test_tile_partition_covers_band_exactly_once():
    rng = random.Random(0x57122)
    for _ in range(300):
        h = rng.randint(1, 90)
        lanes = rng.choice([8, 16])
        bands = rng.randint(1, h + 4)
        covered = []
        for band in split_bands_aligned(h, bands, lanes):
            y0, y1 = band
            t0, t1 = tile_partition(band, lanes)
            assert y0 <= t0 <= t1 <= y1
            assert (t1 - t0) % lanes == 0, "tiled span must be whole tiles"
            # aligned band starts make the leading scalar span empty
            if y0 % lanes == 0:
                assert t0 == y0
            covered.extend(range(y0, t0))
            covered.extend(range(t0, t1))
            covered.extend(range(t1, y1))
        assert covered == list(range(h)), "each source row handled exactly once"


def test_single_band_is_whole_image_kernel():
    # one band [0, h) must reduce to the sequential kernel's partition:
    # tiled rows [0, h - h % lanes), scalar remainder at the bottom
    for h in [0, 1, 7, 8, 16, 17, 33, 600]:
        for lanes in [8, 16]:
            plan = split_bands_aligned(h, 1, lanes)
            if h == 0:
                assert plan == []
                continue
            assert plan == [(0, h)]
            t0, t1 = tile_partition((0, h), lanes)
            assert t0 == 0
            assert t1 == h - h % lanes


def test_banded_transpose_matches_oracle():
    rng = random.Random(0x57123)
    for case in range(200):
        h = rng.randint(1, 40)
        w = rng.randint(1, 40)
        lanes = rng.choice([8, 16])
        bands = rng.randint(1, h + 4)
        img = [rng.randint(0, 255) for _ in range(h * w)]
        got, writes = banded_transpose(img, h, w, bands, lanes)
        assert got == naive_transpose(img, h, w), (
            f"case {case}: h={h} w={w} lanes={lanes} bands={bands} diverged"
        )
        # every destination cell written exactly once: the stripes are
        # disjoint even though they interleave in the flat buffer
        assert writes == [1] * (w * h)


def test_banded_transpose_strided_sources():
    rng = random.Random(0x57124)
    for _ in range(100):
        h = rng.randint(1, 30)
        w = rng.randint(1, 30)
        stride = w + rng.randint(1, 9)
        lanes = rng.choice([8, 16])
        bands = rng.randint(1, h + 4)
        backing = [rng.randint(0, 255) for _ in range(h * stride)]
        got, writes = banded_transpose(backing, h, w, bands, lanes, stride=stride)
        assert got == naive_transpose(backing, h, w, stride=stride)
        assert writes == [1] * (w * h)


def test_degenerate_shapes():
    for h, w in [(1, 20), (20, 1), (1, 1), (16, 16), (8, 8)]:
        for lanes in [8, 16]:
            for bands in [1, 2, h, h + 5]:
                img = list(range(h * w))
                got, writes = banded_transpose(img, h, w, bands, lanes)
                assert got == naive_transpose(img, h, w)
                assert writes == [1] * (w * h)
