"""AOT path: lowering to HLO text + manifest generation.

Checks the compile contract the rust runtime depends on: HLO text parses
as a module with the right parameter/result shapes, the manifest schema
is complete, and quick-grid generation is reproducible.
"""

import json
import pathlib
import tempfile

import pytest

from compile import aot, model


def test_lower_produces_hlo_text():
    fn = model.build_op("erode", 3, 3)
    text = aot.lower_fn(fn, 32, 32)
    assert "HloModule" in text
    assert "u8[32,32]" in text  # parameter shape
    assert len(text) > 500


def test_lower_transpose_swaps_result_shape():
    text = aot.lower_fn(model.build_transpose(), 24, 48)
    assert "u8[24,48]" in text
    assert "u8[48,24]" in text


def test_quick_grid_writes_manifest_and_files():
    with tempfile.TemporaryDirectory() as d:
        rc = aot.main(["--outdir", d, "--quick"])
        assert rc == 0
        out = pathlib.Path(d)
        manifest = json.loads((out / "manifest.json").read_text())
        assert manifest["format"] == 1
        assert manifest["dtype"] == "u8"
        arts = manifest["artifacts"]
        # quick grid: 2 ops x 1 window x 1 shape + 1 transpose
        assert len(arts) == 3
        for a in arts:
            f = out / a["file"]
            assert f.exists(), a["file"]
            text = f.read_text()
            assert "HloModule" in text
            assert a["hlo_bytes"] == len(text)
            assert set(a) >= {
                "name", "kind", "op", "height", "width", "w_x", "w_y",
                "method", "vertical", "dtype", "input", "output", "sha256",
            }
            assert a["input"]["shape"] == [a["height"], a["width"]]


def test_variant_names_are_unique_and_stable():
    metas = [m for _, _, m in aot.build_variants(
        aot.SHAPES, aot.OPS, aot.WINDOWS, "hybrid", "transpose")]
    names = [m["name"] for m in metas]
    assert len(names) == len(set(names))
    assert aot.variant_name("erode", 600, 800, 3, 3) == "erode_600x800_w3x3"
    # default grid: 2 shapes x (5 ops x 3 windows + 1 transpose) = 32
    assert len(names) == 32


def test_lowering_is_deterministic():
    fn = model.build_op("dilate", 3, 3)
    a = aot.lower_fn(fn, 16, 16)
    b = aot.lower_fn(fn, 16, 16)
    # module text may embed no timestamps — must be byte-identical
    assert a == b


@pytest.mark.parametrize("method", ["linear", "vhgw", "hybrid"])
def test_all_methods_lower(method):
    fn = model.build_op("erode", 3, 3, method=method)
    text = aot.lower_fn(fn, 16, 16)
    assert "HloModule" in text
