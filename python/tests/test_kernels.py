"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

This is the CORE correctness signal of the compile path — every kernel
method (linear / logtree / vhgw), both window axes, both reductions,
exact equality on integer dtypes.  Hypothesis sweeps shapes, windows and
dtypes.
"""

import json
import pathlib

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import morph1d, ref
from compile.kernels import transpose as tk

RNG = np.random.default_rng(0xC0FFEE)

PARITY_FIXTURE = (
    pathlib.Path(__file__).resolve().parents[2] / "fixtures" / "parity_u16.json"
)


def rand_img(h, w, dtype=np.uint8):
    info = np.iinfo(dtype)
    return jnp.asarray(
        RNG.integers(info.min, int(info.max) + 1, size=(h, w), dtype=dtype)
    )


odd_windows = st.integers(0, 7).map(lambda k: 2 * k + 1)
small_dims = st.tuples(st.integers(1, 40), st.integers(1, 40))


# ---------------------------------------------------------------------------
# fixed-case grid (fast, exhaustive over methods)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", morph1d.METHODS)
@pytest.mark.parametrize("op", ["min", "max"])
@pytest.mark.parametrize("window", [1, 3, 5, 9, 15, 31])
def test_rows_pass_matches_ref(method, op, window):
    img = rand_img(37, 53)
    want = ref.filter_1d(img, window, axis=0, op=op)
    got = morph1d.filter_rows(img, window, op, method)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("method", morph1d.METHODS)
@pytest.mark.parametrize("op", ["min", "max"])
@pytest.mark.parametrize("window", [1, 3, 5, 9, 15, 31])
def test_cols_pass_matches_ref(method, op, window):
    img = rand_img(29, 61)
    want = ref.filter_1d(img, window, axis=1, op=op)
    got = morph1d.filter_cols(img, window, op, method)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("window", [3, 7, 15])
def test_window_larger_than_axis(window):
    img = rand_img(4, 5)
    for axis, fn in [(0, morph1d.filter_rows), (1, morph1d.filter_cols)]:
        want = ref.filter_1d(img, window * 3 + (window % 2 == 0), axis, "min")
        got = fn(img, window * 3 + (window % 2 == 0), "min", "vhgw")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_even_window_rejected():
    img = rand_img(8, 8)
    with pytest.raises(ValueError):
        morph1d.filter_rows(img, 4, "min")
    with pytest.raises(ValueError):
        morph1d.filter_cols(img, 2, "max")
    with pytest.raises(ValueError):
        morph1d.filter_rows(img, 3, "median")  # bad op
    with pytest.raises(ValueError):
        morph1d.filter_rows(img, 3, "min", method="quantum")


def test_vhgw_oracle_matches_direct_oracle():
    img = rand_img(33, 47)
    for axis in (0, 1):
        for op in ("min", "max"):
            a = ref.filter_1d(img, 9, axis, op)
            b = ref.vhgw_1d(img, 9, axis, op)
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# hypothesis sweeps
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(dims=small_dims, window=odd_windows, op=st.sampled_from(["min", "max"]),
       method=st.sampled_from(morph1d.METHODS), seed=st.integers(0, 2**31))
def test_rows_pass_hypothesis(dims, window, op, method, seed):
    h, w = dims
    rng = np.random.default_rng(seed)
    img = jnp.asarray(rng.integers(0, 256, size=(h, w), dtype=np.uint8))
    want = ref.filter_1d(img, window, axis=0, op=op)
    got = morph1d.filter_rows(img, window, op, method)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=40, deadline=None)
@given(dims=small_dims, window=odd_windows, op=st.sampled_from(["min", "max"]),
       method=st.sampled_from(morph1d.METHODS), seed=st.integers(0, 2**31))
def test_cols_pass_hypothesis(dims, window, op, method, seed):
    h, w = dims
    rng = np.random.default_rng(seed)
    img = jnp.asarray(rng.integers(0, 256, size=(h, w), dtype=np.uint8))
    want = ref.filter_1d(img, window, axis=1, op=op)
    got = morph1d.filter_cols(img, window, op, method)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(dims=small_dims, seed=st.integers(0, 2**31),
       dtype=st.sampled_from([np.uint8, np.uint16, np.int32]),
       tile=st.sampled_from([4, 8, 16]))
def test_transpose_tiled_hypothesis(dims, seed, dtype, tile):
    h, w = dims
    rng = np.random.default_rng(seed)
    info = np.iinfo(dtype)
    img = jnp.asarray(rng.integers(info.min, int(info.max) + 1, size=(h, w), dtype=dtype))
    got = tk.transpose_tiled(img, tile=tile)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(img).T)


@settings(max_examples=20, deadline=None)
@given(dims=st.tuples(st.integers(1, 24), st.integers(1, 24)),
       window=st.integers(0, 5).map(lambda k: 2 * k + 1),
       seed=st.integers(0, 2**31))
def test_u16_images_also_supported(dims, window, seed):
    h, w = dims
    rng = np.random.default_rng(seed)
    img = jnp.asarray(rng.integers(0, 65536, size=(h, w), dtype=np.uint16))
    want = ref.filter_1d(img, window, axis=0, op="min")
    got = morph1d.filter_rows(img, window, "min", "logtree")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# Table-1 transpose kernels
# ---------------------------------------------------------------------------


def test_transpose8x8_u16():
    m = jnp.asarray(RNG.integers(0, 65536, size=(8, 8), dtype=np.uint16))
    np.testing.assert_array_equal(np.asarray(tk.transpose8x8_u16(m)), np.asarray(m).T)


def test_transpose16x16_u8():
    m = jnp.asarray(RNG.integers(0, 256, size=(16, 16), dtype=np.uint8))
    np.testing.assert_array_equal(np.asarray(tk.transpose16x16_u8(m)), np.asarray(m).T)


def test_transpose_specializations_validate_input():
    bad = jnp.zeros((8, 8), jnp.uint8)
    with pytest.raises(ValueError):
        tk.transpose8x8_u16(bad)
    with pytest.raises(ValueError):
        tk.transpose16x16_u8(jnp.zeros((16, 16), jnp.uint16))
    with pytest.raises(ValueError):
        tk.transpose_tiled(jnp.zeros((4, 4, 4), jnp.uint8))


# ---------------------------------------------------------------------------
# cross-language u16 golden fixture (shared with rust/tests/parity_fixture.rs)
# ---------------------------------------------------------------------------


def _parity_cases():
    doc = json.loads(PARITY_FIXTURE.read_text())
    assert doc["format"] == 1 and doc["dtype"] == "u16"
    return doc["cases"]


def test_u16_parity_fixture_matches_ref_oracle():
    ops = {
        "erode": ref.erode_u16,
        "dilate": ref.dilate_u16,
        "opening": ref.opening_u16,
        "closing": ref.closing_u16,
    }
    cases = _parity_cases()
    assert len(cases) >= 6
    for c in cases:
        h, w = c["height"], c["width"]
        img = np.array(c["input"], dtype=np.uint16).reshape(h, w)
        want = np.array(c["expected"], dtype=np.uint16).reshape(h, w)
        got = np.asarray(ops[c["op"]](jnp.asarray(img), c["w_x"], c["w_y"]))
        np.testing.assert_array_equal(got, want, err_msg=c["name"])


def test_u16_wrappers_reject_wrong_dtype():
    img8 = jnp.zeros((4, 4), jnp.uint8)
    with pytest.raises(ValueError):
        ref.erode_u16(img8, 3, 3)


def test_u16_wrappers_preserve_values_above_u8_range():
    img = jnp.full((6, 6), 40_000, jnp.uint16)
    out = np.asarray(ref.closing_u16(img, 3, 3))
    assert out.dtype == np.uint16
    np.testing.assert_array_equal(out, np.asarray(img))


def test_combine_count_census():
    # linear: w-1 combines; logtree: floor(log2 w)+1; vhgw: 3 flat
    assert morph1d.combine_count(31, "linear") == 30
    assert morph1d.combine_count(31, "logtree") == 5
    assert morph1d.combine_count(31, "vhgw") == 3
    assert morph1d.combine_count(1, "linear") == 0
    # the optimized tree must never exceed the paper's chain
    for w in range(3, 123, 2):
        assert morph1d.combine_count(w, "logtree") <= morph1d.combine_count(w, "linear")
