"""Serve-baseline mirror checks: the staged pipeline's admission and
warm-ahead arithmetic (``python/tools/mirror_counts.py:serve_baseline``)
must agree with the committed ``BENCH_serve.json`` CI gate baseline —
the same closed forms ``bench_harness::serve`` computes on the rust
side.

Pure arithmetic, no jax: runs anywhere pytest does.
"""

import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[2]
sys.path.insert(0, str(REPO / "python" / "tools"))

import mirror_counts as mc  # noqa: E402

BASELINE = REPO / "rust" / "benches" / "baselines" / "BENCH_serve.json"


def headline():
    return json.loads(BASELINE.read_text())["headline"]


def test_serve_baseline_matches_committed_headline():
    got = mc.serve_baseline()["headline"]
    want = headline()
    assert set(got) == set(want)
    for key, value in want.items():
        assert got[key] == value, key


def test_admission_arithmetic():
    # 4 plan families, each bursting SATURATE_BURST requests against a
    # per-key budget of SATURATE_BUDGET: accepted = families x budget,
    # shed = the rest, and nothing vanishes
    h = headline()
    assert h["admission_budget_per_key"] == mc.SATURATE_BUDGET
    assert h["saturated_accepted"] == 4 * mc.SATURATE_BUDGET
    assert h["saturated_shed"] == 4 * (mc.SATURATE_BURST - mc.SATURATE_BUDGET)
    assert h["saturated_accepted"] + h["saturated_shed"] == 4 * mc.SATURATE_BURST
    assert h["stage_depth_bound"] == mc.SATURATE_STAGE_CAP


def test_warm_ahead_doubles_plan_touches():
    # the resolve stage warms every request's plan before execute
    # touches it: each request is two cache touches, so
    # hits == 2 * requests - resolutions
    h = headline()
    assert h["plan_hits"] == 2 * h["requests"] - h["plan_resolutions"]
    assert h["plan_resolutions_per_request"] == h["plan_resolutions"] / h["requests"]


def test_saturated_tail_is_budget_times_parallel_price():
    # tail latency of an accepted same-key burst: the last of BUDGET
    # requests waits for the whole burst at the fused-serving price
    h = headline()
    mix = mc.rows_simd_linear(240, 320, 7)
    mix += mc.cols_simd_linear(240, 320, 7)
    want = mc.SATURATE_BUDGET * mc.parallel_price_ns(mix, mc.SERVE_FUSED_WORKERS) / 1e6
    assert abs(h["saturated_tail_ms"] - want) < 1e-12
