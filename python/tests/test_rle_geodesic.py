"""Scenario-engine mirrors: RLE interval morphology + geodesic
reconstruction vs the dense oracles.

Python half of the cross-language contract pinned by
``rust/tests/rle_geodesic.rs``: interval erode/dilate must be
bit-identical to the dense separable oracle on every 0/255 image, and
reconstruction must reach the dense fixpoint with the library's sweep
accounting (every executed sweep counts, including the final one that
proves stability).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref

RNG = np.random.default_rng(0xA11CE)

odd_windows = st.integers(0, 4).map(lambda k: 2 * k + 1)
small_dims = st.tuples(st.integers(1, 36), st.integers(1, 44))
densities = st.sampled_from([0, 1, 5, 20, 50, 80, 100])


def bernoulli_mask(h, w, fg_percent, dtype=np.uint8):
    info = np.iinfo(dtype)
    fg = RNG.random(size=(h, w)) * 100 < fg_percent
    return jnp.asarray(np.where(fg, info.max, info.min).astype(dtype))


# ---------------------------------------------------------------------------
# RLE interval engine vs the dense oracle
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(dims=small_dims, wx=odd_windows, wy=odd_windows, density=densities)
def test_rle_matches_dense_oracle(dims, wx, wy, density):
    h, w = dims
    mask = bernoulli_mask(h, w, density)
    assert jnp.array_equal(ref.rle_erode(mask, wx, wy), ref.erode(mask, wx, wy))
    assert jnp.array_equal(ref.rle_dilate(mask, wx, wy), ref.dilate(mask, wx, wy))


@settings(max_examples=25, deadline=None)
@given(dims=small_dims, density=st.integers(0, 100))
def test_rle_round_trip_is_lossless(dims, density):
    h, w = dims
    mask = bernoulli_mask(h, w, density)
    runs = ref.rle_encode(mask)
    assert jnp.array_equal(ref.rle_decode(runs, w), mask)
    fg = sum(e - s for row in runs for s, e in row)
    assert fg == int(jnp.count_nonzero(mask))


def test_rle_runs_stay_sorted_maximal():
    mask = bernoulli_mask(20, 40, 30)
    for img in [ref.rle_erode(mask, 5, 3), ref.rle_dilate(mask, 5, 3)]:
        for row in ref.rle_encode(img):
            for (s0, e0), (s1, e1) in zip(row, row[1:]):
                assert e0 < s1, "runs must be sorted with a gap"
            for s, e in row:
                assert 0 <= s < e <= img.shape[1]


def test_rle_edge_geometries():
    # the same hand-built pathologies as rust/tests/rle_geodesic.rs:
    # full row, empty row, 1-px runs, runs touching both borders,
    # border-anchored runs, an interior run, a lone pixel
    img = np.zeros((9, 12), dtype=np.uint8)
    img[0, :] = 255
    img[2, ::2] = 255
    img[3, [0, 11]] = 255
    img[4, :3] = 255
    img[5, 9:] = 255
    img[6, 3:9] = 255
    img[7:, 5] = 255
    img = jnp.asarray(img)
    for wx, wy in [(1, 1), (3, 1), (1, 3), (3, 3), (5, 7), (13, 3)]:
        assert jnp.array_equal(ref.rle_erode(img, wx, wy), ref.erode(img, wx, wy)), (wx, wy)
        assert jnp.array_equal(ref.rle_dilate(img, wx, wy), ref.dilate(img, wx, wy)), (wx, wy)


def test_rle_u16_uses_the_u16_identities():
    mask = bernoulli_mask(17, 22, 30, dtype=np.uint16)
    assert jnp.array_equal(ref.rle_erode(mask, 5, 3), ref.erode_u16(mask, 5, 3))
    assert jnp.array_equal(ref.rle_dilate(mask, 5, 3), ref.dilate_u16(mask, 5, 3))


def test_rle_rejects_gray_and_even_windows():
    gray = jnp.asarray(np.full((4, 4), 17, dtype=np.uint8))
    with pytest.raises(ValueError, match="no run-length form"):
        ref.rle_encode(gray)
    mask = bernoulli_mask(4, 4, 50)
    with pytest.raises(ValueError, match="odd"):
        ref.rle_erode(mask, 4, 3)


# ---------------------------------------------------------------------------
# geodesic reconstruction vs a naive sweep oracle
# ---------------------------------------------------------------------------


def naive_reconstruct(marker, mask, wx, wy):
    """Pixel-by-pixel in-bounds max-window sweeps, library accounting."""
    marker, mask = np.asarray(marker), np.asarray(mask)
    h, w = mask.shape
    wing_y, wing_x = wy // 2, wx // 2
    cur = np.minimum(marker, mask)
    sweeps = 0
    while True:
        sweeps += 1
        nxt = np.empty_like(cur)
        for y in range(h):
            for x in range(w):
                win = cur[
                    max(y - wing_y, 0) : y + wing_y + 1,
                    max(x - wing_x, 0) : x + wing_x + 1,
                ]
                nxt[y, x] = min(win.max(), mask[y, x])
        if np.array_equal(nxt, cur):
            return jnp.asarray(cur), sweeps
        cur = nxt


@settings(max_examples=10, deadline=None)
@given(
    dims=st.tuples(st.integers(4, 20), st.integers(4, 24)),
    wx=st.sampled_from([1, 3, 5]),
    wy=st.sampled_from([1, 3, 5]),
)
def test_reconstruction_matches_naive_oracle(dims, wx, wy):
    h, w = dims
    mask = bernoulli_mask(h, w, 50)
    seed = RNG.random(size=(h, w)) < 0.05
    marker = jnp.asarray(np.where(seed, np.asarray(mask), 0).astype(np.uint8))
    want, want_sweeps = naive_reconstruct(marker, mask, wx, wy)
    got, sweeps = ref.reconstruct_by_dilation(marker, mask, wx, wy)
    assert jnp.array_equal(got, want)
    assert sweeps == want_sweeps


def test_reconstruction_by_erosion_is_the_dual():
    mask = bernoulli_mask(12, 16, 50)
    seed = bernoulli_mask(12, 16, 5)
    marker = jnp.minimum(seed, mask)
    by_dil, s1 = ref.reconstruct_by_dilation(marker, mask, 3, 3)
    # complement duality: rec_by_erosion(~marker, ~mask) == ~rec_by_dilation
    inv = lambda a: jnp.asarray(255 - np.asarray(a), dtype=jnp.uint8)  # noqa: E731
    by_ero, s2 = ref.reconstruct_by_erosion(inv(marker), inv(mask), 3, 3)
    assert jnp.array_equal(by_ero, inv(by_dil))
    assert s1 == s2


def test_reconstruction_without_change_counts_one_proving_sweep():
    # marker already at the fixpoint: the loop still runs (and counts)
    # exactly the sweep that proves nothing changes
    mask = jnp.asarray(np.full((6, 6), 255, dtype=np.uint8))
    out, sweeps = ref.reconstruct_by_dilation(mask, mask, 3, 3)
    assert jnp.array_equal(out, mask)
    assert sweeps == 1


def test_bench_checkerboard_workload_counts():
    # the BENCH_rle.json reconstruction workload (bench_harness::rle):
    # 60x80 checkerboard (cell 8, foreground on odd cells), marker = top
    # row of the mask.  Odd cells corner-touch, so the fixpoint is the
    # full mask; the sweep count here is what mirror_counts.py bakes
    # into the committed baseline.
    h, w, cell = 60, 80, 8
    y, x = np.indices((h, w))
    mask = jnp.asarray(np.where((y // cell + x // cell) % 2 == 1, 255, 0).astype(np.uint8))
    marker = jnp.asarray(np.where(y == 0, np.asarray(mask), 0).astype(np.uint8))
    out, sweeps = ref.reconstruct_by_dilation(marker, mask, 3, 3)
    assert jnp.array_equal(out, mask)
    assert int(jnp.count_nonzero(out)) == int(jnp.count_nonzero(mask))
    assert sweeps >= h // 2
