"""L2 correctness: the separable-morphology graph vs the oracle —
separability, derived ops, method/strategy equivalence, hybrid routing.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

RNG = np.random.default_rng(0xBEEF)


def rand_img(h, w):
    return jnp.asarray(RNG.integers(0, 256, size=(h, w), dtype=np.uint8))


@pytest.mark.parametrize("method", model.PASS_METHODS)
@pytest.mark.parametrize("vertical", model.VERTICAL_STRATEGIES)
@pytest.mark.parametrize("se", [(3, 3), (5, 9), (9, 5), (1, 7), (7, 1)])
def test_erode_dilate_match_oracle(method, vertical, se):
    w_x, w_y = se
    img = rand_img(33, 45)
    np.testing.assert_array_equal(
        np.asarray(model.erode(img, w_x, w_y, method, vertical)),
        np.asarray(ref.erode(img, w_x, w_y)),
    )
    np.testing.assert_array_equal(
        np.asarray(model.dilate(img, w_x, w_y, method, vertical)),
        np.asarray(ref.dilate(img, w_x, w_y)),
    )


def test_separability_against_nonseparable_oracle():
    img = rand_img(24, 28)
    for (w_x, w_y) in [(3, 5), (7, 3)]:
        np.testing.assert_array_equal(
            np.asarray(model.erode(img, w_x, w_y)),
            np.asarray(ref.erode_nonseparable(img, w_x, w_y)),
        )
        np.testing.assert_array_equal(
            np.asarray(model.dilate(img, w_x, w_y)),
            np.asarray(ref.dilate_nonseparable(img, w_x, w_y)),
        )


@pytest.mark.parametrize("op", model.OPS)
def test_all_ops_match_ref(op):
    img = rand_img(30, 34)
    got = model.op_fn(op)(img, 5, 3)
    want = getattr(ref, op if op != "erode" and op != "dilate" else op)(img, 5, 3)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_build_op_returns_one_tuple():
    img = rand_img(16, 16)
    fn = model.build_op("erode", 3, 3)
    out = fn(img)
    assert isinstance(out, tuple) and len(out) == 1
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(ref.erode(img, 3, 3)))


def test_build_transpose():
    img = rand_img(20, 12)
    (out,) = model.build_transpose()(img)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(img).T)


def test_hybrid_resolution_uses_paper_thresholds():
    assert model.resolve_method("hybrid", 69, model.W_Y0) == "linear"
    assert model.resolve_method("hybrid", 71, model.W_Y0) == "vhgw"
    assert model.resolve_method("hybrid", 59, model.W_X0) == "linear"
    assert model.resolve_method("hybrid", 61, model.W_X0) == "vhgw"
    assert model.resolve_method("vhgw", 3, model.W_Y0) == "vhgw"
    with pytest.raises(ValueError):
        model.resolve_method("banana", 3, 69)


def test_unknown_op_rejected():
    with pytest.raises(ValueError):
        model.build_op("sharpen", 3, 3)
    img = rand_img(8, 8)
    with pytest.raises(ValueError):
        model.pass_cols(img, 3, "min", vertical="diagonal")


def test_opening_antiextensive_closing_extensive():
    img = rand_img(26, 26)
    o = np.asarray(model.opening(img, 5, 5))
    c = np.asarray(model.closing(img, 5, 5))
    a = np.asarray(img)
    assert (o <= a).all()
    assert (c >= a).all()


def test_gradient_tophat_blackhat_nonnegative():
    img = rand_img(22, 22)
    for op in ("gradient", "tophat", "blackhat"):
        out = np.asarray(model.op_fn(op)(img, 5, 5))
        assert out.dtype == np.uint8
        assert (out <= 255).all()  # no wraparound artifacts
        # value at a flat region must be 0: make a flat image and check
    flat = jnp.full((12, 12), 77, jnp.uint8)
    for op in ("gradient", "tophat", "blackhat"):
        out = np.asarray(model.op_fn(op)(flat, 3, 3))
        assert (out == 0).all(), op


@settings(max_examples=20, deadline=None)
@given(
    dims=st.tuples(st.integers(2, 32), st.integers(2, 32)),
    wx=st.integers(0, 4).map(lambda k: 2 * k + 1),
    wy=st.integers(0, 4).map(lambda k: 2 * k + 1),
    method=st.sampled_from(model.PASS_METHODS),
    seed=st.integers(0, 2**31),
)
def test_erode_hypothesis(dims, wx, wy, method, seed):
    h, w = dims
    rng = np.random.default_rng(seed)
    img = jnp.asarray(rng.integers(0, 256, size=(h, w), dtype=np.uint8))
    got = model.erode(img, wx, wy, method)
    want = ref.erode(img, wx, wy)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_duality_model_level():
    img = rand_img(20, 24)
    inv = 255 - img
    e = np.asarray(model.erode(img, 5, 7))
    d = np.asarray(model.dilate(inv, 5, 7))
    np.testing.assert_array_equal(e, 255 - d)
