"""Independent verification of the plan-executor geometry in
``rust/src/morphology/plan.rs``.

The plan--execute API evaluates a whole op *chain* (erode/dilate plus
the derived ops lowered to primitive erode/dilate/subtract steps) on a
region of interest by filtering one haloed **block** around the ROI and
cropping, instead of filtering the full image.  Its correctness claim
is the PR-3 ROI halo-containment theorem lifted to chains:

    crop(chain(full), roi) == crop(chain(block), roi - block_origin)

where ``block = clamp(roi expanded by depth * wing per axis)`` and
``depth`` is the length of the longest erode/dilate dependency path
through the chain (1 for erode/dilate/gradient, 2 for open/close/
tophat/blackhat, summed across chain elements).

Why it holds: every primitive morph step's output pixel depends only on
inputs within ``wing`` of it, so after ``depth`` steps the dependency
cone has radius ``depth * wing``; inside the block, pixels closer than
the remaining cone radius to an *interior* block edge may differ from
the full-image computation, but the ROI sits at distance >= the full
cone radius from every interior edge, and wherever the halo was clamped
the block edge *coincides with the image edge*, where the kernel's
border handling (identity padding, or replicate pre-padding of the
block, applied per morph step exactly like the rust lowering) matches
the full-image behaviour.  Subtract steps are pointwise (radius 0).

This file checks the claim with numpy oracles over randomized chains,
windows, borders and ROI positions (corner / edge-touching / interior),
mirroring the plan's lowering and block arithmetic exactly.
"""

import random

import numpy as np

# ---- numpy oracle of the primitive kernels ------------------------------


def _pad(img, wing_y, wing_x, mode, fill=None):
    if mode == "edge":
        return np.pad(img, ((wing_y, wing_y), (wing_x, wing_x)), mode="edge")
    return np.pad(
        img, ((wing_y, wing_y), (wing_x, wing_x)), mode="constant", constant_values=fill
    )


def _morph_identity(img, op, w_x, w_y):
    """Separable windowed min/max with identity (constant) padding."""
    wing_x, wing_y = w_x // 2, w_y // 2
    fill = 255 if op == "min" else 0
    p = _pad(img, wing_y, wing_x, "constant", fill)
    h, w = img.shape
    out = None
    red = np.minimum if op == "min" else np.maximum
    for dy in range(w_y):
        for dx in range(w_x):
            tile = p[dy : dy + h, dx : dx + w]
            out = tile if out is None else red(out, tile)
    return out


def morph(img, op, w_x, w_y, border):
    """One primitive erode/dilate step, mirroring the rust lowering of
    Border::Replicate: replicate-pad by the wings, filter with identity
    borders, crop the center back."""
    if border == "replicate":
        wing_x, wing_y = w_x // 2, w_y // 2
        p = _pad(img, wing_y, wing_x, "edge")
        full = _morph_identity(p, op, w_x, w_y)
        h, w = img.shape
        return full[wing_y : wing_y + h, wing_x : wing_x + w]
    return _morph_identity(img, op, w_x, w_y)


def sat_sub(a, b):
    return np.where(a > b, a - b, np.zeros_like(a))


# ---- mirror of plan.rs lowering -----------------------------------------

DEPTH = {
    "erode": 1,
    "dilate": 1,
    "gradient": 1,
    "open": 2,
    "close": 2,
    "tophat": 2,
    "blackhat": 2,
}


def run_op(img, op, w_x, w_y, border):
    if op == "erode":
        return morph(img, "min", w_x, w_y, border)
    if op == "dilate":
        return morph(img, "max", w_x, w_y, border)
    if op == "open":
        return run_op(run_op(img, "erode", w_x, w_y, border), "dilate", w_x, w_y, border)
    if op == "close":
        return run_op(run_op(img, "dilate", w_x, w_y, border), "erode", w_x, w_y, border)
    if op == "gradient":
        return sat_sub(
            run_op(img, "dilate", w_x, w_y, border), run_op(img, "erode", w_x, w_y, border)
        )
    if op == "tophat":
        return sat_sub(img, run_op(img, "open", w_x, w_y, border))
    if op == "blackhat":
        return sat_sub(run_op(img, "close", w_x, w_y, border), img)
    raise ValueError(op)


def run_chain(img, ops, w_x, w_y, border):
    out = img
    for op in ops:
        out = run_op(out, op, w_x, w_y, border)
    return out


def plan_block(roi, h, w, ops, w_x, w_y):
    """Mirror of FilterPlan::build's ROI -> block arithmetic."""
    y, x, rh, rw = roi
    depth = sum(DEPTH[o] for o in ops)
    hx, hy = depth * (w_x // 2), depth * (w_y // 2)
    y0, x0 = max(0, y - hy), max(0, x - hx)
    y1, x1 = min(h, y + rh + hy), min(w, x + rw + hx)
    return y0, x0, y1, x1


def plan_roi(img, ops, w_x, w_y, border, roi):
    """What the rust plan computes: chain on the haloed block, cropped."""
    h, w = img.shape
    y0, x0, y1, x1 = plan_block(roi, h, w, ops, w_x, w_y)
    block = img[y0:y1, x0:x1]
    out = run_chain(block, ops, w_x, w_y, border)
    y, x, rh, rw = roi
    return out[y - y0 : y - y0 + rh, x - x0 : x - x0 + rw]


# ---- the property -------------------------------------------------------

OPS = list(DEPTH)


def _random_roi(rng, h, w):
    kind = rng.randrange(4)
    if kind == 0:  # corner
        rh, rw = rng.randint(1, h), rng.randint(1, w)
        return (0, 0, rh, rw)
    if kind == 1:  # bottom-right corner (both edges clamped)
        rh, rw = rng.randint(1, h), rng.randint(1, w)
        return (h - rh, w - rw, rh, rw)
    if kind == 2:  # full image
        return (0, 0, h, w)
    rh, rw = rng.randint(1, h), rng.randint(1, w)
    return (rng.randint(0, h - rh), rng.randint(0, w - rw), rh, rw)


def test_chain_roi_block_equals_cropped_chain():
    rng = random.Random(0xC4A1)
    for case in range(250):
        h = rng.randint(1, 26)
        w = rng.randint(1, 26)
        img = np.asarray(
            [[rng.randrange(256) for _ in range(w)] for _ in range(h)], dtype=np.int64
        )
        n_ops = rng.choice([1, 1, 1, 2, 3])
        ops = [rng.choice(OPS) for _ in range(n_ops)]
        w_x = rng.choice([1, 3, 5, 7])
        w_y = rng.choice([1, 3, 5, 7])
        border = rng.choice(["identity", "replicate"])
        roi = _random_roi(rng, h, w)

        full = run_chain(img, ops, w_x, w_y, border)
        y, x, rh, rw = roi
        want = full[y : y + rh, x : x + rw]
        got = plan_roi(img, ops, w_x, w_y, border, roi)
        assert got.shape == want.shape, (case, ops, roi)
        assert np.array_equal(got, want), (
            case,
            ops,
            (w_x, w_y),
            border,
            roi,
            (h, w),
        )


def test_depth_is_tight_for_single_morphs():
    # sanity: with one wing less of halo the block computation must be
    # able to differ (the theorem's bound is not slack) — checked on a
    # gradient-of-open chain where the cone is deepest
    rng = random.Random(7)
    mismatches = 0
    for _ in range(200):
        h = w = 16
        img = np.asarray(
            [[rng.randrange(256) for _ in range(w)] for _ in range(h)], dtype=np.int64
        )
        ops = ["open"]
        w_x = w_y = 5
        roi = (6, 6, 4, 4)
        # under-haloed block: depth 1 instead of 2
        y, x, rh, rw = roi
        hy = hx = 1 * 2
        y0, x0 = max(0, y - hy), max(0, x - hx)
        y1, x1 = min(h, y + rh + hy), min(w, x + rw + hx)
        block = img[y0:y1, x0:x1]
        got = run_chain(block, ops, w_x, w_y, "identity")[
            y - y0 : y - y0 + rh, x - x0 : x - x0 + rw
        ]
        want = run_chain(img, ops, w_x, w_y, "identity")[y : y + rh, x : x + rw]
        if not np.array_equal(got, want):
            mismatches += 1
    assert mismatches > 0, "under-halo must be observable, else the bound is slack"


if __name__ == "__main__":
    test_chain_roi_block_equals_cropped_chain()
    test_depth_is_tight_for_single_morphs()
    print("plan geometry: all properties hold")
