#!/usr/bin/env python3
"""Instruction-count mirror of the rust Counting backend.

Transcribes, loop for loop, the accounting of the passes that feed the
CI perf baselines (``rust/benches/baselines/BENCH_*.json``):

* ``rows_scalar_vhgw`` / ``rows_simd_vhgw`` / ``rows_simd_linear``
  (``rust/src/morphology/vhgw.rs`` / ``linear.rs``) on the 800x600 u8
  workload at the smoke windows — the Fig. 3 headline ratios,
* the w = 121 linear erosion with its vertical pass forced through the
  section-5.2.1 transpose sandwich (``rows_simd_linear`` + both
  ``transpose_image`` tilings + ``rows_simd_linear`` on the transposed
  image) — the instruction mix behind the band-parallel scaling sweep
  (saturation point, speedups, bandwidth ceiling, and the
  serial-transpose ceiling the banded transpose lifted),
* the closed-form banded-transpose headlines (``BENCH_transpose.json``)
  — ``transpose_breakdown`` below mirrors
  ``CostModel::transpose_breakdown`` term by term (it is loop-exact
  against the tile censuses, so closed form and counted mix agree
  exactly): sequential throughput at both depths, the full-cost banded
  speedup at P = 4, the ``Parallelism::Auto`` demotion decision, and
  the in-sandwich (fork-amortized) speedup,
* ``cols_scalar_vhgw`` / ``cols_simd_linear`` / the section-5.2.1
  transpose sandwich (``transpose_image`` tiling + ``rows_simd_vhgw``
  on the transposed 800x600 image) — the Fig. 4 vertical-pass headline
  ratios, and
* the section-4 tile transposes (scalar element loops vs the vtrn
  networks) — the Table 1 scalar/SIMD headline ratios, and
* the streamed-serving plan-cache census (``BENCH_serve.json``) — a
  pure count of distinct canonical plan keys in the fixed
  ``bench_harness::serve`` request mix, mirroring the
  ``FilterSpec::canonical_for`` position-independence rule (interior
  ROIs key by shape, so the crop sweep counts once; the staged
  pipeline's resolve stage warms every plan ahead of execute, so each
  request is two cache touches) — plus the saturation arithmetic
  (per-key admission budgets against burst sizes) and the
  model-priced fused-batch throughput: the hot family's per-image mix
  (erode 7x7 on 240x320, both passes Linear) priced either as ``n``
  independent fork-joins or as ONE fork-join over the fused ``n*h``
  extent (``FusedPlan``), at ``SERVE_FUSED_WORKERS`` workers.  Compute
  is identical either way; the gated batch-64 ratio is pure
  fork/band-overhead recovery, and
* the scenario-engine headlines (``BENCH_rle.json``) — the closed-form
  RLE-vs-dense cost ratio (``CostModel::estimate_rle_cost`` against the
  default-config separable estimate) at the sparse headline density,
  its 0.005-step crossover scan, and a pixel-by-pixel simulation of the
  geodesic-reconstruction sweep loop on the checkerboard workload
  (``bench_harness::rle``), with the library's sweep accounting (the
  final fixpoint-proving sweep counts).

Counts are pure functions of the loop structure (no pixel data), so the
mirror and the rust Counting backend must agree exactly; prices are the
same closed-form cost model (``CostModel::exynos5422``).  This is how
the *committed* baselines were generated in an environment without a
rust toolchain; with one available, ``cargo run --release -- bench
smoke --update-baselines`` regenerates them from the rust side and must
reproduce these numbers (the CI gate allows 10 percent, the expected
agreement is exact).

Usage:  python3 python/tools/mirror_counts.py [outdir]
        (default outdir: rust/benches/baselines)
"""

import json
import math
import os
import sys

# CostModel::exynos5422 (rust/src/costmodel/mod.rs) — keep in sync.
CYCLES = {
    "simd_load": 1.1,
    "simd_load_u": 1.58,
    "simd_store": 1.0,
    "simd_minmax": 0.62,
    "simd_permute": 1.0,
    "simd_combine": 0.5,
    "simd_reinterpret": 0.0,
    "scalar_load": 1.8,
    "scalar_store": 1.8,
    "scalar_cmp": 0.8,
    "scalar_alu": 0.5,
}
FREQ_GHZ = 2.0
BW_BYTES_PER_CYCLE = 1.1
CALL_OVERHEAD_NS = 18.0
FORK_NS = 15_000.0
# zero-copy band jobs (ImageView executor): job boxing + queue send +
# latch only — the old 4 us value also absorbed the per-band staging
# copies the pre-view executor performed
BAND_OVERHEAD_NS = 1_200.0
SATURATION_EPSILON = 0.05

H, W = 600, 800  # synth::paper_image dimensions (u8, px = 1 byte)
LANES = 16
SMOKE_WINDOWS = [3, 31, 61, 91]
SCALING_WINDOW = 121
MAX_WORKERS = 16
# bench_harness::serve fused-batch headline constants — keep in sync.
SERVE_FUSED_WORKERS = 4
FUSED_BATCH_SIZES = [1, 8, 64]
# bench_harness::serve saturation headline constants — keep in sync.
SATURATE_BURST = 64
SATURATE_BUDGET = 8
SATURATE_STAGE_CAP = 8
PAPER_WY0 = 69
PAPER_WX0 = 59


class Mix(dict):
    """Instruction histogram + streamed bytes."""

    def __init__(self):
        super().__init__({k: 0 for k in CYCLES})
        self.stream = 0

    def bump(self, cls, n=1):
        self[cls] += n

    def __iadd__(self, other):
        for k in CYCLES:
            self[k] += other[k]
        self.stream += other.stream
        return self

    def compute_ns(self):
        return sum(self[k] * CYCLES[k] for k in CYCLES) / FREQ_GHZ

    def memory_ns(self):
        return self.stream / BW_BYTES_PER_CYCLE / FREQ_GHZ

    def price_ns(self):
        return self.compute_ns() + self.memory_ns() + CALL_OVERHEAD_NS

    def price_ns_marginal(self):
        # CostModel::price_ns_marginal — no per-call overhead
        return self.compute_ns() + self.memory_ns()


def rows_simd_linear(h, w, window, lanes=LANES, px=1):
    m = Mix()
    wing = window // 2
    wv = w - w % lanes
    chunks = wv // lanes
    m.stream += 2 * h * w * px
    y = 0
    while y < h:
        pair = y + 1 < h
        c0 = max(0, (y + 1) - wing)
        c1 = min(y + wing, h - 1)
        top = y >= wing
        bot = y + wing + 1 < h
        loads = 1 + (c1 - c0) + (1 if top else 0) + (1 if pair and bot else 0)
        mms = (c1 - c0) + (1 if top else 0) + (1 if pair and bot else 0)
        stores = 1 + (1 if pair else 0)
        m.bump("scalar_alu", 2 * chunks)
        m.bump("simd_load", loads * chunks)
        m.bump("simd_minmax", mms * chunks)
        m.bump("simd_store", stores * chunks)
        for _ in range(wv, w):  # scalar tail (empty at w=800)
            m.bump("scalar_alu", 2)
            m.bump("scalar_load", loads)
            m.bump("scalar_cmp", mms)
            m.bump("scalar_store", stores)
        y += 2
    return m


def rows_simd_vhgw(h, w, window, lanes=LANES, px=1):
    m = Mix()
    wing = window // 2
    nseg = math.ceil((h + 2 * wing) / window)
    ph = nseg * window
    wv = w - w % lanes
    chunks = wv // lanes
    tail = w - wv
    m.stream += ((2 * h * w + ph * w) + (ph * w + h * w)) * px
    for i in range(ph):  # R scan
        if i % window == 0:
            m.bump("scalar_alu", chunks)
            m.bump("simd_load", chunks)
            m.bump("simd_store", chunks)
            m.bump("scalar_load", tail)
            m.bump("scalar_store", tail)
        else:
            m.bump("scalar_alu", chunks)
            m.bump("simd_load", 2 * chunks)
            m.bump("simd_minmax", chunks)
            m.bump("simd_store", chunks)
            m.bump("scalar_load", 2 * tail)
            m.bump("scalar_cmp", tail)
            m.bump("scalar_store", tail)
    for i in reversed(range(ph)):  # S scan fused with merge
        seg_last = i % window == window - 1
        loads, mms, stores = 1, 0, 1
        if not seg_last:
            loads += 1
            mms += 1
        if i < h:
            loads += 1
            mms += 1
            stores += 1
        m.bump("scalar_alu", chunks)
        m.bump("simd_load", loads * chunks)
        m.bump("simd_minmax", mms * chunks)
        m.bump("simd_store", stores * chunks)
        m.bump("scalar_load", loads * tail)
        m.bump("scalar_cmp", mms * tail)
        m.bump("scalar_store", stores * tail)
    return m


def rows_scalar_vhgw(h, w, window, px=1):
    m = Mix()
    wing = window // 2
    nseg = math.ceil((h + 2 * wing) / window)
    ph = nseg * window
    m.stream += ((2 * h * w + ph * w) + (ph * w + h * w)) * px
    for i in range(ph):  # R scan
        m.bump("scalar_alu", 1)
        if i % window == 0:
            m.bump("scalar_load", w)
            m.bump("scalar_store", w)
        else:
            m.bump("scalar_alu", w)
            m.bump("scalar_load", 2 * w)
            m.bump("scalar_cmp", w)
            m.bump("scalar_store", w)
    for i in reversed(range(ph)):  # S scan
        seg_last = i % window == window - 1
        m.bump("scalar_alu", 1)
        loads, cmps, stores = 1, 0, 1
        if not seg_last:
            loads += 1
            cmps += 1
        if i < h:
            loads += 1
            cmps += 1
            stores += 1
        m.bump("scalar_alu", w)
        m.bump("scalar_load", loads * w)
        m.bump("scalar_cmp", cmps * w)
        m.bump("scalar_store", stores * w)
    return m


def cols_simd_linear(h, w, window):
    m = Mix()
    wv = w - w % LANES
    chunks = wv // LANES
    tail = w - wv
    m.stream += 2 * h * w
    for _ in range(h):
        m.bump("scalar_alu", 2 * chunks)
        m.bump("simd_load_u", window * chunks)
        m.bump("simd_minmax", (window - 1) * chunks)
        m.bump("simd_store", chunks)
        m.bump("scalar_alu", tail)
        m.bump("scalar_load", window * tail)
        m.bump("scalar_cmp", (window - 1) * tail)
        m.bump("scalar_store", tail)
    return m


def cols_scalar_vhgw(h, w, window):
    # rust/src/morphology/vhgw.rs::cols_scalar_vhgw_into — per-row 1-D
    # vHGW, R is one padded row; pval loads only inside [wing, wing+w)
    m = Mix()
    wing = window // 2
    nseg = math.ceil((w + 2 * wing) / window)
    pw = nseg * window
    m.stream += 2 * h * w + h * w
    for _ in range(h):
        # R: per-segment prefix, ascending
        m.bump("scalar_alu", pw)
        m.bump("scalar_load", w)  # pval in-range loads
        m.bump("scalar_load", pw - nseg)  # r[j-1] on non-segment-start j
        m.bump("scalar_cmp", pw - nseg)
        m.bump("scalar_store", pw)
        # S fused with merge, descending
        m.bump("scalar_alu", pw)
        m.bump("scalar_load", w)  # pval in-range loads
        m.bump("scalar_cmp", pw - nseg)  # carry combine on non-seg-last j
        m.bump("scalar_load", w)  # r[j + window - 1] for j < w
        m.bump("scalar_cmp", w)
        m.bump("scalar_store", w)
    return m


# -- section-4 transposes ---------------------------------------------------

# per-tile census of the vtrn networks (transpose/neon.rs; reinterprets
# are free and skipped): loads, stores, permutes (vtrn), combines
# (vget/vcombine)
TILE16 = {"simd_load": 16, "simd_store": 16, "simd_permute": 24, "simd_combine": 48}
TILE8 = {"simd_load": 8, "simd_store": 8, "simd_permute": 8, "simd_combine": 24}


def transpose_image(h, w):
    # rust/src/transpose/mod.rs::transpose_image (u8): 16x16 NEON tiles
    # for the aligned interior, scalar element copies for the edges
    m = Mix()
    m.stream += 2 * h * w
    th, tw = h - h % 16, w - w % 16
    tiles = (th // 16) * (tw // 16)
    for cls, n in TILE16.items():
        m.bump(cls, tiles * n)
    edge = h * (w - tw) + (h - th) * tw
    m.bump("scalar_load", edge)
    m.bump("scalar_store", edge)
    return m


def tile_transpose_mix(census, scalar_elems):
    simd = Mix()
    for cls, n in census.items():
        simd.bump(cls, n)
    scalar = Mix()
    scalar.bump("scalar_load", scalar_elems)
    scalar.bump("scalar_store", scalar_elems)
    return scalar, simd


def parallel_price_ns(mix, workers):
    if workers <= 1:
        return mix.price_ns()
    return (
        mix.compute_ns() / workers
        + mix.memory_ns()
        + CALL_OVERHEAD_NS
        + FORK_NS
        + BAND_OVERHEAD_NS * workers
    )


def transpose_breakdown(h, w, lanes=LANES, px=1, workers=1):
    """CostModel::transpose_breakdown, term by term: closed-form price
    of one whole-image section-4 tile transpose as ``workers`` tile-row
    bands.  Loop-exact against ``transpose_image`` (same tile census,
    same edge census, same 2*h*w stream), so the closed form and a
    counted mix agree exactly.  Returns (compute_ns, memory_ns,
    overhead_ns)."""
    census = TILE16 if lanes == 16 else TILE8
    tile_cycles = (
        census["simd_load"] * CYCLES["simd_load"]
        + census["simd_store"] * CYCLES["simd_store"]
        + census["simd_permute"] * CYCLES["simd_permute"]
        + census["simd_combine"] * CYCLES["simd_combine"]
    )
    th, tw = h - h % lanes, w - w % lanes
    tiles = (th // lanes) * (tw // lanes)
    edge_px = h * (w - tw) + (h - th) * tw
    edge_cycles = edge_px * (CYCLES["scalar_load"] + CYCLES["scalar_store"])
    compute_ns = (tiles * tile_cycles + edge_cycles) / FREQ_GHZ
    stream_bytes = 2.0 * (h * w * px)
    memory_ns = stream_bytes / BW_BYTES_PER_CYCLE / FREQ_GHZ
    if workers <= 1:
        return compute_ns, memory_ns, CALL_OVERHEAD_NS
    return (
        compute_ns / workers,
        memory_ns,
        CALL_OVERHEAD_NS + FORK_NS + BAND_OVERHEAD_NS * workers,
    )


def plan_transpose_workers(h, w, lanes=LANES, px=1, max_workers=8):
    # CostModel::plan_transpose_workers -> plan_workers: argmin of the
    # parallel price, demoted to 1 unless >=10% better than sequential
    compute_ns, memory_ns, _ = transpose_breakdown(h, w, lanes, px, 1)
    seq = compute_ns + memory_ns + CALL_OVERHEAD_NS
    best, best_ns = 1, seq
    for p in range(2, max(max_workers, 1) + 1):
        t = (
            compute_ns / p
            + memory_ns
            + (CALL_OVERHEAD_NS + FORK_NS + BAND_OVERHEAD_NS * p)
        )
        if t < best_ns:
            best, best_ns = p, t
    return 1 if best_ns > seq * 0.9 else best


def fig3_baseline():
    headline = {}
    series = {}
    for w in SMOKE_WINDOWS:
        ns = [
            rows_scalar_vhgw(H, W, w).price_ns(),
            rows_simd_vhgw(H, W, w).price_ns(),
            rows_simd_linear(H, W, w).price_ns(),
        ]
        ns.append(ns[2] if w <= PAPER_WY0 else ns[1])  # hybrid
        series[w] = ns
    headline["vhgw_simd_speedup_w31"] = series[31][0] / series[31][1]
    headline["linear_speedup_w3"] = series[3][0] / series[3][2]
    headline["crossover_wy0"] = max(w for w in SMOKE_WINDOWS if series[w][2] <= series[w][1])
    return (
        {
            "bench": "fig3",
            "workload": "horizontal erosion on 800x600 u8",
            "headline": headline,
        },
        series,
    )


def fig3_u16_baseline():
    # mirrors bench_harness::fig3::run_u16 at host_iters=0 +
    # scaling::fig3u16_json: the same loop structures at 16-bit depth --
    # 8 lanes per 128-bit op (so SIMD chunk counts double) and 2 bytes
    # per element (so streamed bytes double); scalar instruction counts
    # are depth-invariant.
    headline = {}
    series = {}
    for w in SMOKE_WINDOWS:
        ns = [
            rows_scalar_vhgw(H, W, w, px=2).price_ns(),
            rows_simd_vhgw(H, W, w, lanes=8, px=2).price_ns(),
            rows_simd_linear(H, W, w, lanes=8, px=2).price_ns(),
        ]
        ns.append(ns[2] if w <= PAPER_WY0 else ns[1])  # hybrid
        series[w] = ns
    headline["vhgw_simd_speedup_w31"] = series[31][0] / series[31][1]
    headline["linear_speedup_w3"] = series[3][0] / series[3][2]
    # continuous series-shape anchors (the discrete crossover stays
    # informational on the rust side -- never in the gated baseline)
    headline["linear_w61_over_w31"] = series[61][2] / series[31][2]
    headline["vhgw_simd_w61_over_w31"] = series[61][1] / series[31][1]
    return (
        {
            "bench": "fig3u16",
            "workload": "horizontal erosion on 800x600 u16",
            "headline": headline,
        },
        series,
    )


def fig4_baseline():
    # mirrors bench_harness::fig4::run at host_iters=0 + scaling::fig4_json
    headline = {}
    series = {}
    for w in SMOKE_WINDOWS:
        sandwich = Mix()
        sandwich += transpose_image(H, W)
        sandwich += rows_simd_vhgw(W, H, w)  # rows pass on the 800x600 transposed image
        sandwich += transpose_image(W, H)
        ns = [
            cols_scalar_vhgw(H, W, w).price_ns(),
            sandwich.price_ns(),
            cols_simd_linear(H, W, w).price_ns(),
        ]
        ns.append(ns[2] if w <= PAPER_WX0 else ns[1])  # hybrid
        series[w] = ns
    headline["vhgw_sandwich_speedup_w31"] = series[31][0] / series[31][1]
    headline["linear_speedup_w3"] = series[3][0] / series[3][2]
    # continuous near-crossover anchor; the discrete crossover itself is
    # informational only (w=61 sits on a ~1% margin — a step function
    # would make the +/-10% gate a cliff)
    headline["linear_vs_sandwich_w61"] = series[61][2] / series[61][1]
    return (
        {
            "bench": "fig4",
            "workload": "vertical erosion on 800x600 u8",
            "headline": headline,
        },
        series,
    )


def table1_baseline():
    # mirrors bench_harness::table1::run_model + scaling::table1_json:
    # marginal (no per-call overhead) model prices of the section-4 tile
    # transposes, scalar vs NEON
    s8, v8 = tile_transpose_mix(TILE8, 64)
    s16, v16 = tile_transpose_mix(TILE16, 256)
    headline = {
        "scalar_ns_8x8": s8.price_ns_marginal(),
        "simd_ns_8x8": v8.price_ns_marginal(),
        "ratio_8x8": s8.price_ns_marginal() / v8.price_ns_marginal(),
        "scalar_ns_16x16": s16.price_ns_marginal(),
        "simd_ns_16x16": v16.price_ns_marginal(),
        "ratio_16x16": s16.price_ns_marginal() / v16.price_ns_marginal(),
    }
    return {
        "bench": "table1",
        "workload": "tile transpose 8x8.16 / 16x16.8",
        "headline": headline,
    }


def scaling_baseline():
    # bench_harness::scaling::run with the banded-sandwich workload: a
    # w=121 linear erosion whose vertical pass is forced through the
    # section-5.2.1 transpose sandwich, so the counted mix is the rows
    # pass + both tile transposes + the middle rows pass over the
    # transposed (800x600) image — every phase the banded executors
    # cover.
    mix = Mix()
    mix += rows_simd_linear(H, W, SCALING_WINDOW)
    mix += transpose_image(H, W)
    mix += rows_simd_linear(W, H, SCALING_WINDOW)
    mix += transpose_image(W, H)
    seq = mix.price_ns()
    speedup = lambda p: seq / parallel_price_ns(mix, p)  # noqa: E731
    saturation = MAX_WORKERS
    for p in range(1, MAX_WORKERS):
        cur, nxt = parallel_price_ns(mix, p), parallel_price_ns(mix, p + 1)
        if nxt >= cur * (1.0 - SATURATION_EPSILON):
            saturation = p
            break
    margin = parallel_price_ns(mix, saturation + 1) / (
        parallel_price_ns(mix, saturation) * (1.0 - SATURATION_EPSILON)
    )
    # banded-transpose ceiling vs the old serial-transpose ceiling: with
    # the two transposes' compute pinned serial, Amdahl moves it from
    # (C+M)/M down to (C+M)/(M+Ct) — their ratio is the headroom the
    # banded transpose bought
    transpose_compute_ns = (
        transpose_breakdown(H, W, 16, 1, 1)[0] + transpose_breakdown(W, H, 16, 1, 1)[0]
    )
    total = mix.compute_ns() + mix.memory_ns()
    ceiling = total / mix.memory_ns()
    ceiling_serial_transpose = total / (mix.memory_ns() + transpose_compute_ns)
    return (
        {
            "bench": "scaling",
            "workload": (
                f"erode {SCALING_WINDOW}x{SCALING_WINDOW} linear "
                f"transpose-sandwich on {H}x{W} u8"
            ),
            "headline": {
                "saturation_workers": saturation,
                "speedup_at_2": speedup(2),
                "speedup_at_4": speedup(4),
                "speedup_at_saturation": speedup(saturation),
                "ceiling": ceiling,
                "ceiling_serial_transpose": ceiling_serial_transpose,
                "transpose_ceiling_lift": ceiling / ceiling_serial_transpose,
            },
        },
        {"seq_ns": seq, "mix": dict(mix), "stream": mix.stream, "margin": margin},
    )


def transpose_baseline():
    # mirrors bench_harness::transpose::{run_model, to_json}: per depth
    # case on the paper shape, the marginal sequential price of the
    # whole-image tile network, its throughput, the full-cost banded
    # speedup at P=4, the Auto band decision, and the in-sandwich
    # (fork-amortized) speedup — all closed-form via transpose_breakdown
    headline = {}
    for case, lanes, px in [("16x16_u8", 16, 1), ("8x8_u16", 8, 2)]:
        sc, sm, so = transpose_breakdown(H, W, lanes, px, 1)
        pc, pm, po = transpose_breakdown(H, W, lanes, px, 4)
        seq_marginal = sc + sm
        headline[f"seq_ns_{case}"] = seq_marginal
        headline[f"mpx_s_{case}"] = (H * W) / seq_marginal * 1000.0
        headline[f"banded_speedup4_{case}"] = (sc + sm + so) / (pc + pm + po)
        headline[f"auto_bands_{case}"] = plan_transpose_workers(H, W, lanes, px, 8)
        headline[f"sandwich_speedup4_{case}"] = seq_marginal / (pc + pm)
    return {
        "bench": "transpose",
        "workload": f"banded tile transpose on {H}x{W}",
        "headline": headline,
    }


def serve_baseline():
    # Mirrors bench_harness::serve::{smoke_requests, run_smoke, to_json}:
    # the headline is a pure COUNT of distinct canonical plan keys in the
    # fixed request mix (1 worker => resolutions == distinct keys), so
    # the mirror enumerates the same requests and applies the same
    # canonicalization rule (FilterSpec::canonical_for): an interior ROI
    # (full chain-halo on every side) keys on its shape at the canonical
    # anchor; a clamped one would keep its position.
    sh, sw = 240, 320  # serve::SERVE_H x serve::SERVE_W
    group = 16  # serve::GROUP
    keys = set()
    # erode 7x7 full u8 (halo = depth 1 * wing 3)
    for _ in range(group):
        keys.add(("erode", 7, 7, "u8", None))
    # erode 7x7 + 64x80 ROI swept over interior positions
    roi_h, roi_w, halo = 64, 80, 3
    for i in range(group):
        y = 3 + (i * 10) % (sh - roi_h - 6)
        x = 3 + (i * 13) % (sw - roi_w - 6)
        interior = (
            y >= halo
            and x >= halo
            and y + roi_h + halo <= sh
            and x + roi_w + halo <= sw
        )
        assert interior, f"smoke sweep position ({y},{x}) must be interior"
        # canonical anchor: position-independent key
        keys.add(("erode", 7, 7, "u8", (halo, halo, roi_h, roi_w)))
    # tophat 5x5 full u8
    for _ in range(group):
        keys.add(("tophat", 5, 5, "u8", None))
    # dilate 5x5 full u16
    for _ in range(group):
        keys.add(("dilate", 5, 5, "u16", None))
    requests = 4 * group
    resolutions = len(keys)
    # fused-batch throughput, model-priced (serve::fused_model): the hot
    # family's per-image mix is erode 7x7 on sh x sw — window 7 sits
    # far below both hybrid crossovers (wy0=69, wx0=59), so the rust
    # Counting run resolves to the two Linear passes exactly.
    per_image = Mix()
    per_image += rows_simd_linear(sh, sw, 7)
    per_image += cols_simd_linear(sh, sw, 7)

    def scaled(n):
        total = Mix()
        for _ in range(n):
            total += per_image
        return total

    def fused_ns(n):
        # ONE fork-join over the fused n*h-row extent
        return parallel_price_ns(scaled(n), SERVE_FUSED_WORKERS)

    def seq_ns(n):
        # n independent fork-joins through the per-image plan
        return n * parallel_price_ns(per_image, SERVE_FUSED_WORKERS)

    headline = {
        "requests": requests,
        "plan_resolutions": resolutions,
        # the staged pipeline's resolve stage warms every request's plan
        # ahead of execute: each request is TWO cache touches, so a
        # family of G requests scores 1 resolution + (2G - 1) hits
        "plan_hits": 2 * requests - resolutions,
        "plan_resolutions_per_request": resolutions / requests,
        "fused_speedup_batch64": seq_ns(64) / fused_ns(64),
    }
    for n in FUSED_BATCH_SIZES:
        headline[f"images_per_sec_batch{n}"] = 1e9 * n / fused_ns(n)
    # saturation headlines (serve::saturate_model): a same-key burst
    # that outruns service admits exactly the per-key budget, so the
    # 4-family accepted/shed totals are arithmetic; the modeled tail is
    # the last admitted hot-family request draining through one lane
    # (budget requests, each priced like the fused model's per-image
    # pass pair at SERVE_FUSED_WORKERS)
    headline["admission_budget_per_key"] = SATURATE_BUDGET
    headline["saturated_accepted"] = 4 * SATURATE_BUDGET
    headline["saturated_shed"] = 4 * (SATURATE_BURST - SATURATE_BUDGET)
    headline["saturated_tail_ms"] = (
        SATURATE_BUDGET * parallel_price_ns(per_image, SERVE_FUSED_WORKERS) / 1e6
    )
    headline["stage_depth_bound"] = SATURATE_STAGE_CAP
    return {
        "bench": "serve",
        "workload": (
            f"streamed serve: 4 plan families x {group} reqs on {sh}x{sw} "
            "(interior ROI sweep collapses to one plan), 1 worker; "
            f"fused-batch throughput modeled at {SERVE_FUSED_WORKERS} workers; "
            f"saturation modeled at budget {SATURATE_BUDGET}/key x "
            f"{SATURATE_BURST}-req bursts"
        ),
        "headline": headline,
    }


# -- scenario engines (BENCH_rle.json) --------------------------------------

# CostModel RLE constants (rust/src/costmodel/mod.rs) — keep in sync.
RLE_SCAN_CYCLES = 0.5
RLE_RUN_CYCLES = 8.0
RLE_MERGE_CYCLES = 3.0
# bench_harness::rle headline constants — keep in sync.
RLE_WX = RLE_WY = 7
RLE_STEPS = 1
RLE_SPARSE_DENSITY = 0.05
RECON_H, RECON_W, RECON_CELL = 60, 80, 8
RECON_WX = RECON_WY = 3


def runs_per_row(w, density):
    # costmodel::runs_per_row — Bernoulli expectation of maximal runs
    if w == 0:
        return 0.0
    d = min(max(density, 0.0), 1.0)
    return (w - 1) * d * (1.0 - d) + d


def estimate_separable_cost(h, w, w_x, w_y, lanes=LANES, px=1):
    """CostModel::estimate_separable_cost under MorphConfig::default()
    (hybrid dispatch at the paper thresholds, Direct vertical, simd on)
    — returns (compute_ns, memory_ns)."""
    ld, ldu = CYCLES["simd_load"], CYCLES["simd_load_u"]
    st, mm, salu = CYCLES["simd_store"], CYCLES["simd_minmax"], CYCLES["scalar_alu"]
    if h == 0 or w == 0:
        return 0.0, 0.0
    pixels = h * w
    compute = 0.0
    stream = 0.0
    if w_y > 1:
        if w_y <= PAPER_WY0:  # hybrid resolves to Linear
            compute += ((w_y + 1.0) * ld + w_y * mm + 2.0 * st + 2.0 * salu) / (
                2.0 * lanes
            ) * pixels
            stream += 2.0 * pixels * px
        else:  # vHGW R+S chunk census over padded rows
            compute += (
                (5.0 * ld + 3.0 * mm + 3.0 * st + 2.0 * salu) / lanes * ((h + w_y) / h)
            ) * pixels
            stream += 5.0 * pixels * px
    if w_x > 1:
        if w_x <= PAPER_WX0:  # Linear, Direct vertical => no sandwich
            compute += (
                (w_x * ldu + (w_x - 1.0) * mm + st + 2.0 * salu) / lanes
            ) * pixels
            stream += 2.0 * pixels * px
        else:  # vHGW always takes the transpose sandwich
            transpose_px = 2.0 * (2.0 * (ld + st) / 2.0 + 4.0) / lanes
            compute += (
                transpose_px
                + (5.0 * ld + 3.0 * mm + 3.0 * st + 2.0 * salu)
                / lanes
                * ((w + w_x) / w)
            ) * pixels
            stream += (5.0 + 4.0) * pixels * px
    return compute / FREQ_GHZ, stream / BW_BYTES_PER_CYCLE / FREQ_GHZ


def estimate_rle_cost(h, w, w_y, steps, density, px=1):
    # CostModel::estimate_rle_cost: encode+decode stream the image twice
    # and pay a per-pixel scan; each step pays per-run interval work plus
    # a w_y-way per-run merge
    if h == 0 or w == 0:
        return 0.0
    pixels = h * w
    runs = runs_per_row(w, density)
    convert_ns = (
        2.0 * pixels * px / BW_BYTES_PER_CYCLE / FREQ_GHZ
        + pixels * RLE_SCAN_CYCLES / FREQ_GHZ
    )
    per_step = h * runs * RLE_RUN_CYCLES + h * w_y * runs * RLE_MERGE_CYCLES
    return convert_ns + steps * per_step / FREQ_GHZ


def rle_speedup(h, w, w_x, w_y, steps, density, px=1):
    rle = estimate_rle_cost(h, w, w_y, steps, density, px)
    if rle <= 0.0:
        return 1.0
    comp, mem = estimate_separable_cost(h, w, w_x, w_y, LANES, px)
    return steps * (comp + mem) / rle


def rle_crossover_density(h, w, w_x, w_y, steps, px=1):
    # the same 0.005 accumulation loop as CostModel::rle_crossover_density
    # (f64 addition is identical in both languages)
    d = 0.0
    while d <= 1.0:
        if rle_speedup(h, w, w_x, w_y, steps, d, px) <= 1.0:
            return d
        d += 0.005
    return 1.0


def rle_reconstruct_counts():
    """bench_harness::rle::run_recon, swept pixel-by-pixel: reconstruct
    the 60x80 checkerboard (cell 8, FG on odd cells) from its top row
    with 3x3 geodesic dilation, counting every executed sweep including
    the final fixpoint-proving one (geodesic::reconstruct_with_plan)."""
    h, w, cell = RECON_H, RECON_W, RECON_CELL
    mask = [
        [255 if ((y // cell) + (x // cell)) % 2 == 1 else 0 for x in range(w)]
        for y in range(h)
    ]
    marker = [mask[0][:]] + [[0] * w for _ in range(h - 1)]
    cur = [[min(marker[y][x], mask[y][x]) for x in range(w)] for y in range(h)]
    sweeps = 0
    while True:
        sweeps += 1
        nxt = []
        for y in range(h):
            row = []
            for x in range(w):
                m = 0
                for yy in range(max(y - 1, 0), min(y + 1, h - 1) + 1):
                    v = max(cur[yy][max(x - 1, 0) : x + 2])
                    if v > m:
                        m = v
                row.append(min(m, mask[y][x]))
            nxt.append(row)
        if nxt == cur:
            fg = sum(1 for row in cur for v in row if v > 0)
            return sweeps, fg
        cur = nxt


def rle_baseline():
    # mirrors bench_harness::rle::{run_smoke, to_json}
    sweeps, fg = rle_reconstruct_counts()
    headline = {
        "rle_speedup_sparse5pct": rle_speedup(
            H, W, RLE_WX, RLE_WY, RLE_STEPS, RLE_SPARSE_DENSITY
        ),
        "rle_crossover_density": rle_crossover_density(H, W, RLE_WX, RLE_WY, RLE_STEPS),
        "reconstruct_sweeps": sweeps,
        "reconstruct_foreground": fg,
    }
    return {
        "bench": "rle",
        "workload": (
            f"rle model: erode {RLE_WX}x{RLE_WY} on {W}x{H} u8 at density "
            f"{RLE_SPARSE_DENSITY} (crossover scanned at 0.005); live reconstruct "
            f"{RECON_WX}x{RECON_WY} on {RECON_W}x{RECON_H} checkerboard (cell "
            f"{RECON_CELL}) seeded from its top row"
        ),
        "headline": headline,
    }


def main():
    outdir = sys.argv[1] if len(sys.argv) > 1 else "rust/benches/baselines"
    os.makedirs(outdir, exist_ok=True)
    fig3, series = fig3_baseline()
    fig3u16, series16 = fig3_u16_baseline()
    fig4, series4 = fig4_baseline()
    table1 = table1_baseline()
    scaling, debug = scaling_baseline()
    serve = serve_baseline()
    rle = rle_baseline()
    transpose = transpose_baseline()
    for name, doc in [
        ("BENCH_fig3.json", fig3),
        ("BENCH_fig3_u16.json", fig3u16),
        ("BENCH_fig4.json", fig4),
        ("BENCH_table1.json", table1),
        ("BENCH_scaling.json", scaling),
        ("BENCH_serve.json", serve),
        ("BENCH_rle.json", rle),
        ("BENCH_transpose.json", transpose),
    ]:
        path = os.path.join(outdir, name)
        with open(path, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {path}")
    print("\nfig3 model ns per window [vhgw, vhgw_simd, linear_simd, hybrid]:")
    for w, ns in series.items():
        print(f"  w={w:3d}: " + "  ".join(f"{v:12.1f}" for v in ns))
    print("\nfig3 u16 model ns per window [vhgw, vhgw_simd, linear_simd, hybrid]:")
    for w, ns in series16.items():
        print(f"  w={w:3d}: " + "  ".join(f"{v:12.1f}" for v in ns))
    print(f"fig3u16 headline: {fig3u16['headline']}")
    print("\nfig4 model ns per window [vhgw, vhgw_simd_T, linear_simd, hybrid]:")
    for w, ns in series4.items():
        print(f"  w={w:3d}: " + "  ".join(f"{v:12.1f}" for v in ns))
    print(f"\nfig4 headline: {fig4['headline']}")
    print(f"table1 headline: {table1['headline']}")
    print(f"\nscaling: seq {debug['seq_ns']:.0f} ns, stream {debug['stream']} B")
    print(f"scaling headline: {scaling['headline']}")
    print(f"saturation boundary margin (want far from 1.0): {debug['margin']:.4f}")
    print(f"serve headline: {serve['headline']}")
    print(f"rle headline: {rle['headline']}")
    print(f"transpose headline: {transpose['headline']}")


if __name__ == "__main__":
    main()
