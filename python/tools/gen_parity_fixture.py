"""Generate the cross-language u16 golden fixture.

Writes ``fixtures/parity_u16.json``: a set of small u16 images plus the
expected outputs of the ref.py oracle (identity borders, separable
form).  Both ``python/tests/test_kernels.py`` and the rust test
``rust/tests/parity_fixture.rs`` consume the file, pinning the two
implementations to one golden truth.

Run from the repository root:

    PYTHONPATH=python python3 python/tools/gen_parity_fixture.py
"""

import json
import pathlib
import sys

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from compile.kernels import ref  # noqa: E402

SEED = 20260727

# (op, height, width, w_x, w_y) — includes degenerate axes and windows
# larger than an axis
CASES = [
    ("erode", 7, 9, 5, 3),
    ("dilate", 7, 9, 3, 5),
    ("erode", 5, 16, 1, 7),
    ("dilate", 16, 5, 7, 1),
    ("opening", 8, 8, 3, 3),
    ("closing", 8, 8, 3, 3),
    ("erode", 1, 11, 3, 3),
    ("dilate", 11, 1, 3, 3),
]

OPS = {
    "erode": ref.erode,
    "dilate": ref.dilate,
    "opening": ref.opening,
    "closing": ref.closing,
}


def main() -> None:
    rng = np.random.default_rng(SEED)
    cases = []
    for op, h, w, w_x, w_y in CASES:
        img = rng.integers(0, 65536, size=(h, w), dtype=np.uint16)
        out = np.asarray(OPS[op](img, w_x, w_y), dtype=np.uint16)
        assert out.shape == (h, w)
        cases.append(
            {
                "name": f"{op}_{h}x{w}_w{w_x}x{w_y}",
                "op": op,
                "height": h,
                "width": w,
                "w_x": w_x,
                "w_y": w_y,
                "input": [int(v) for v in img.ravel()],
                "expected": [int(v) for v in out.ravel()],
            }
        )

    doc = {"format": 1, "dtype": "u16", "seed": SEED, "cases": cases}
    out_path = pathlib.Path(__file__).resolve().parents[2] / "fixtures" / "parity_u16.json"
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(doc, indent=1) + "\n")
    print(f"wrote {out_path} ({len(cases)} cases)")


if __name__ == "__main__":
    main()
