"""L1 Pallas kernels: 1-D running min/max passes for separable morphology.

Three kernel families, mirroring the paper's §5 implementations, adapted
from ARM NEON to the TPU/Pallas idiom (see DESIGN.md §Hardware-Adaptation):

* ``linear``  — the paper's §5.1.2/§5.2.2 *linear implementation*: an
  unrolled chain of ``w`` elementwise min/max ops over statically shifted
  slices of a VMEM block.  On NEON one ``vminq_u8`` combines 16 u8 lanes;
  here one ``jnp.minimum`` on a ``(rows, lanes)`` VMEM tile is the exact
  analogue, with the VPU processing whole tile rows per op.
* ``logtree`` — our optimized variant of ``linear`` (L1 perf deliverable):
  the same window min computed with ⌈log₂ w⌉ doubling steps plus one
  final combine, instead of ``w - 1`` sequential combines.
* ``vhgw``    — van Herk/Gil-Werman: per-segment prefix/suffix scans of
  segment length ``w`` (``lax.cummin``/``cummax`` in VMEM scratch), then
  one combine per output element — O(1) combines per pixel, independent
  of ``w``.  This is the paper's §5.1.1 baseline, vectorized.

Each kernel exists for a window along axis 0 (rows — the paper's
*horizontal pass*) and along axis 1 (cols — the paper's *vertical pass*,
direct strategy).  The transpose-based vertical strategy lives in the L2
model (transpose ∘ rows-pass ∘ transpose).

Blocking strategy: we always tile the NON-window axis, so a block holds
the full (identity-padded) window extent and no halo exchange between
grid steps is needed; every input element is read into VMEM exactly once
per pass.  Kernels run with ``interpret=True`` (CPU PJRT cannot execute
Mosaic custom-calls); the lowered HLO is what ships to the rust runtime.
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from . import ref

# Default tile (lane count) for the tiled, non-window axis.  128 matches
# the TPU VPU lane width; the row-tile for direct col-passes matches the
# 8-sublane register shape.
DEFAULT_LANES = 128
DEFAULT_SUBLANES = 8

METHODS = ("linear", "logtree", "vhgw")


def _comb(op: str):
    if op not in ("min", "max"):
        raise ValueError(f"op must be 'min' or 'max', got {op!r}")
    return jnp.minimum if op == "min" else jnp.maximum


def _cum(op: str):
    return lax.cummin if op == "min" else lax.cummax


def _ceil_to(n: int, m: int) -> int:
    return -(-n // m) * m


def _check_window(window: int):
    if window % 2 != 1 or window < 1:
        raise ValueError(f"window must be odd and >= 1, got {window}")


# ---------------------------------------------------------------------------
# kernel bodies (window along axis 0; axis 1 obtained by symmetric slicing)
# ---------------------------------------------------------------------------


def _linear_body(x_ref, o_ref, *, window, n_out, axis, op):
    """Unrolled min/max chain — paper's linear implementation."""
    comb = _comb(op)

    def shifted(k):
        return x_ref[k : k + n_out, :] if axis == 0 else x_ref[:, k : k + n_out]

    val = shifted(0)
    for k in range(1, window):
        val = comb(val, shifted(k))
    o_ref[...] = val


def _logtree_body(x_ref, o_ref, *, window, n_out, axis, op):
    """Doubling-tree window min/max: ⌈log₂ w⌉ + 1 combines."""
    comb = _comb(op)
    f = x_ref[...]
    span = 1  # f holds running min over [i, i + span)
    while 2 * span <= window:
        if axis == 0:
            f = comb(f[: f.shape[0] - span, :], f[span:, :])
        else:
            f = comb(f[:, : f.shape[1] - span], f[:, span:])
        span *= 2
    # min over [i, i+window) = comb(f(i), f(i + window - span))
    off = window - span
    if axis == 0:
        o_ref[...] = comb(f[0:n_out, :], f[off : off + n_out, :])
    else:
        o_ref[...] = comb(f[:, 0:n_out], f[:, off : off + n_out])


def _vhgw_body(x_ref, o_ref, *, window, n_out, axis, op, nseg):
    """van Herk/Gil-Werman: segment prefix (R) / suffix (S) scans, then
    out[i] = comb(S[i], R[i + w - 1])."""
    comb = _comb(op)
    cum = _cum(op)
    x = x_ref[...]
    if axis == 1:
        x = x.T  # (padded, tile) view of the scan axis first
    tile = x.shape[1]
    segs = x.reshape(nseg, window, tile)
    r = cum(segs, axis=1)
    s = cum(segs[:, ::-1, :], axis=1)[:, ::-1, :]
    r = r.reshape(nseg * window, tile)
    s = s.reshape(nseg * window, tile)
    out = comb(s[0:n_out, :], r[window - 1 : window - 1 + n_out, :])
    o_ref[...] = out if axis == 0 else out.T


_BODIES = {"linear": _linear_body, "logtree": _logtree_body, "vhgw": _vhgw_body}


# ---------------------------------------------------------------------------
# pallas_call wrappers
# ---------------------------------------------------------------------------


def _pass_rows(img, window: int, op: str, method: str, lanes: int):
    """Window along axis 0 (rows); grid tiles axis 1 (cols)."""
    _check_window(window)
    if window == 1:
        return img
    h, w = img.shape
    wing = window // 2
    ident = ref.reduction_identity(op, img.dtype)

    if method == "vhgw":
        nseg = -(-(h + 2 * wing) // window)
        padded_h = nseg * window
    else:
        nseg = 0
        padded_h = h + 2 * wing

    wp = _ceil_to(w, lanes)
    padded = jnp.pad(
        img,
        ((wing, padded_h - h - wing), (0, wp - w)),
        constant_values=ident,
    )

    kwargs = dict(window=window, n_out=h, axis=0, op=op)
    if method == "vhgw":
        kwargs["nseg"] = nseg
    body = functools.partial(_BODIES[method], **kwargs)

    out = pl.pallas_call(
        body,
        grid=(wp // lanes,),
        in_specs=[pl.BlockSpec((padded_h, lanes), lambda i: (0, i))],
        out_specs=pl.BlockSpec((h, lanes), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((h, wp), img.dtype),
        interpret=True,
    )(padded)
    return out[:, :w]


def _pass_cols(img, window: int, op: str, method: str, sublanes: int):
    """Window along axis 1 (cols); grid tiles axis 0 (rows) — the paper's
    direct vertical strategy (unaligned loads on NEON; static offset
    slices of the VMEM block here)."""
    _check_window(window)
    if window == 1:
        return img
    h, w = img.shape
    wing = window // 2
    ident = ref.reduction_identity(op, img.dtype)

    if method == "vhgw":
        nseg = -(-(w + 2 * wing) // window)
        padded_w = nseg * window
    else:
        nseg = 0
        padded_w = w + 2 * wing

    hp = _ceil_to(h, sublanes)
    padded = jnp.pad(
        img,
        ((0, hp - h), (wing, padded_w - w - wing)),
        constant_values=ident,
    )

    kwargs = dict(window=window, n_out=w, axis=1, op=op)
    if method == "vhgw":
        kwargs["nseg"] = nseg
    body = functools.partial(_BODIES[method], **kwargs)

    out = pl.pallas_call(
        body,
        grid=(hp // sublanes,),
        in_specs=[pl.BlockSpec((sublanes, padded_w), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((sublanes, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((hp, w), img.dtype),
        interpret=True,
    )(padded)
    return out[:h, :]


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------


def filter_rows(img, window: int, op: str, method: str = "logtree",
                lanes: int = DEFAULT_LANES):
    """Running ``op`` over a ``window`` of ROWS (paper's horizontal pass).

    ``method`` ∈ {"linear", "logtree", "vhgw"}.
    """
    if method not in METHODS:
        raise ValueError(f"unknown method {method!r}, want one of {METHODS}")
    return _pass_rows(img, window, op, method, lanes)


def filter_cols(img, window: int, op: str, method: str = "logtree",
                sublanes: int = DEFAULT_SUBLANES):
    """Running ``op`` over a ``window`` of COLUMNS (paper's vertical pass,
    direct strategy)."""
    if method not in METHODS:
        raise ValueError(f"unknown method {method!r}, want one of {METHODS}")
    return _pass_cols(img, window, op, method, sublanes)


def min_filter_rows(img, w_y, method="logtree"):
    return filter_rows(img, w_y, "min", method)


def max_filter_rows(img, w_y, method="logtree"):
    return filter_rows(img, w_y, "max", method)


def min_filter_cols(img, w_x, method="logtree"):
    return filter_cols(img, w_x, "min", method)


def max_filter_cols(img, w_x, method="logtree"):
    return filter_cols(img, w_x, "max", method)


def combine_count(window: int, method: str) -> int:
    """Number of elementwise combine ops per block the method performs —
    the cost-model input used in DESIGN.md §Perf (analogue of the paper's
    instruction counts)."""
    _check_window(window)
    if window == 1:
        return 0
    if method == "linear":
        return window - 1
    if method == "logtree":
        return math.floor(math.log2(window)) + 1
    if method == "vhgw":
        # two scans of length w per segment + one final combine, amortized
        # per output element: 2 scan-steps + 1 (the classic "3 comparisons
        # per point" of vHGW).
        return 3
    raise ValueError(f"unknown method {method!r}")
