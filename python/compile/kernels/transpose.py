"""L1 Pallas kernel: tiled matrix/image transpose.

TPU adaptation of the paper's §4 NEON vtrn transpose networks.  On NEON
an 8×8.16 transpose is a fixed network of 32 ``vtrn``/permute
instructions between sixteen 128-bit loads/stores; on TPU the analogous
structure is a *tiled* transpose: the BlockSpec index maps move tile
(i, j) of the input to tile (j, i) of the output (the HBM↔VMEM schedule,
playing the role of the load/store addressing), and the in-VMEM ``.T``
per tile lowers to the Mosaic sublane/lane shuffle network (playing the
role of the vtrn network).

``transpose8x8_u16`` / ``transpose16x16_u8`` are the paper's Table 1
single-tile cases; ``transpose_tiled`` is the whole-image version used by
the L2 vertical pass.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ceil_to(n: int, m: int) -> int:
    return -(-n // m) * m


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...].T


def transpose_tiled(img, tile: int = 8):
    """Transpose a 2-D array via ``tile × tile`` VMEM blocks.

    Dimensions need not be tile multiples; the input is zero-padded to the
    tile grid and the output cropped (pad values never reach live output
    cells).
    """
    if img.ndim != 2:
        raise ValueError(f"expected a 2-D array, got shape {img.shape}")
    if tile < 1:
        raise ValueError(f"tile must be >= 1, got {tile}")
    h, w = img.shape
    hp, wp = _ceil_to(h, tile), _ceil_to(w, tile)
    padded = jnp.pad(img, ((0, hp - h), (0, wp - w)))
    out = pl.pallas_call(
        _kernel,
        grid=(hp // tile, wp // tile),
        in_specs=[pl.BlockSpec((tile, tile), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((tile, tile), lambda i, j: (j, i)),
        out_shape=jax.ShapeDtypeStruct((wp, hp), img.dtype),
        interpret=True,
    )(padded)
    return out[:w, :h]


def transpose8x8_u16(m):
    """Paper Table 1, row 1: 8×8 matrix of 16-bit unsigned ints."""
    if m.shape != (8, 8) or m.dtype != jnp.uint16:
        raise ValueError(f"expected u16[8,8], got {m.dtype}[{m.shape}]")
    return transpose_tiled(m, tile=8)


def transpose16x16_u8(m):
    """Paper Table 1, row 2: 16×16 matrix of 8-bit unsigned ints."""
    if m.shape != (16, 16) or m.dtype != jnp.uint8:
        raise ValueError(f"expected u8[16,16], got {m.dtype}[{m.shape}]")
    return transpose_tiled(m, tile=16)
