"""Pure-jnp reference oracles for the morphology + transpose kernels.

These are the CORE correctness signal: every Pallas kernel in this package
is checked against these functions by pytest (allclose / exact-equal for
integer dtypes).

Conventions (shared by the whole stack — python, HLO artifacts and the
rust native implementations):

* Images are 2-D arrays indexed ``[row, col]`` (= ``[y, x]``).
* A rectangular structuring element of size ``w_x × w_y`` spans ``w_x``
  columns and ``w_y`` rows, anchored at its center; windows are odd
  (``w = 2*wing + 1``).
* Border policy is **identity padding**: out-of-image samples contribute
  the identity of the reduction (``255``/dtype-max for erosion=min,
  ``0``/dtype-min for dilation=max), i.e. the reduction effectively runs
  over the intersection of the window with the image.  Output has the
  same shape as the input.  (The paper "processes edges separately";
  identity padding is the standard way to make that well defined.)

Paper terminology mapping (the paper names passes by their SIMD
iteration direction, which is the *opposite* of the window direction):

* paper "horizontal pass", SE ``1 × w_y``  →  ``min_filter_rows``
  (window spans ``w_y`` ROWS, SIMD runs along contiguous columns).
* paper "vertical pass", SE ``w_x × 1``    →  ``min_filter_cols``
  (window spans ``w_x`` COLUMNS within each row).
"""

import jax.numpy as jnp
import numpy as np


def reduction_identity(op: str, dtype) -> int:
    """Identity element for ``op`` (``"min"`` or ``"max"``) at ``dtype``."""
    if op not in ("min", "max"):
        raise ValueError(f"op must be 'min' or 'max', got {op!r}")
    if jnp.issubdtype(jnp.dtype(dtype), jnp.integer):
        info = jnp.iinfo(dtype)
        return info.max if op == "min" else info.min
    return jnp.inf if op == "min" else -jnp.inf


def _combine(op: str):
    return jnp.minimum if op == "min" else jnp.maximum


def pad_axis(img, wing: int, axis: int, op: str):
    """Pad ``img`` by ``wing`` identity elements on both sides of ``axis``."""
    if wing == 0:
        return img
    pad = [(0, 0)] * img.ndim
    pad[axis] = (wing, wing)
    return jnp.pad(img, pad, constant_values=reduction_identity(op, img.dtype))


def filter_1d(img, window: int, axis: int, op: str):
    """Running min/max of odd ``window`` along ``axis`` (identity borders).

    Direct (O(w)-slices) formulation — the oracle everything else is
    measured against.
    """
    if window % 2 != 1 or window < 1:
        raise ValueError(f"window must be odd and >= 1, got {window}")
    wing = window // 2
    padded = pad_axis(img, wing, axis, op)
    comb = _combine(op)
    n = img.shape[axis]
    out = jnp.take(padded, jnp.arange(0, n), axis=axis)
    for k in range(1, window):
        out = comb(out, jnp.take(padded, jnp.arange(k, k + n), axis=axis))
    return out


def min_filter_rows(img, w_y: int):
    """Paper's *horizontal pass* of erosion: window of ``w_y`` rows."""
    return filter_1d(img, w_y, axis=0, op="min")


def max_filter_rows(img, w_y: int):
    return filter_1d(img, w_y, axis=0, op="max")


def min_filter_cols(img, w_x: int):
    """Paper's *vertical pass* of erosion: window of ``w_x`` columns."""
    return filter_1d(img, w_x, axis=1, op="min")


def max_filter_cols(img, w_x: int):
    return filter_1d(img, w_x, axis=1, op="max")


def erode(img, w_x: int, w_y: int):
    """2-D erosion with a rectangular ``w_x × w_y`` SE (separable form)."""
    return min_filter_cols(min_filter_rows(img, w_y), w_x)


def dilate(img, w_x: int, w_y: int):
    return max_filter_cols(max_filter_rows(img, w_y), w_x)


def erode_nonseparable(img, w_x: int, w_y: int):
    """Direct 2-D sliding-window erosion — used to *prove* separability."""
    wing_x, wing_y = w_x // 2, w_y // 2
    p = pad_axis(pad_axis(img, wing_y, 0, "min"), wing_x, 1, "min")
    h, w = img.shape
    out = None
    for dy in range(w_y):
        for dx in range(w_x):
            tile = p[dy : dy + h, dx : dx + w]
            out = tile if out is None else jnp.minimum(out, tile)
    return out


def dilate_nonseparable(img, w_x: int, w_y: int):
    wing_x, wing_y = w_x // 2, w_y // 2
    p = pad_axis(pad_axis(img, wing_y, 0, "max"), wing_x, 1, "max")
    h, w = img.shape
    out = None
    for dy in range(w_y):
        for dx in range(w_x):
            tile = p[dy : dy + h, dx : dx + w]
            out = tile if out is None else jnp.maximum(out, tile)
    return out


def opening(img, w_x: int, w_y: int):
    return dilate(erode(img, w_x, w_y), w_x, w_y)


def closing(img, w_x: int, w_y: int):
    return erode(dilate(img, w_x, w_y), w_x, w_y)


def gradient(img, w_x: int, w_y: int):
    """Morphological gradient = dilation - erosion (non-negative by
    construction since dilation >= erosion pointwise)."""
    return dilate(img, w_x, w_y) - erode(img, w_x, w_y)


def tophat(img, w_x: int, w_y: int):
    """White top-hat = src - opening (saturating for unsigned dtypes)."""
    o = opening(img, w_x, w_y)
    return jnp.where(img > o, img - o, jnp.zeros_like(img))


def blackhat(img, w_x: int, w_y: int):
    """Black top-hat = closing - src (saturating for unsigned dtypes)."""
    c = closing(img, w_x, w_y)
    return jnp.where(c > img, c - img, jnp.zeros_like(img))


def transpose(img):
    """Matrix/image transpose oracle."""
    return jnp.transpose(img)


# ---------------------------------------------------------------------------
# explicit u16 mirrors
#
# ``filter_1d`` and friends are dtype-generic already; these wrappers pin
# the 16-bit contract the rust stack's ``MorphPixel`` u16 path mirrors
# (identity = 65535/0, dtype preserved end to end) and are what the
# cross-language golden fixture (fixtures/parity_u16.json, generated by
# python/tools/gen_parity_fixture.py) is built from.
# ---------------------------------------------------------------------------


def _as_u16(img):
    img = jnp.asarray(img)
    if img.dtype != jnp.uint16:
        raise ValueError(f"expected a uint16 image, got {img.dtype}")
    return img


def erode_u16(img, w_x: int, w_y: int):
    """2-D u16 erosion (identity borders = 65535), dtype-preserving."""
    out = erode(_as_u16(img), w_x, w_y)
    assert out.dtype == jnp.uint16
    return out


def dilate_u16(img, w_x: int, w_y: int):
    """2-D u16 dilation (identity borders = 0), dtype-preserving."""
    out = dilate(_as_u16(img), w_x, w_y)
    assert out.dtype == jnp.uint16
    return out


def opening_u16(img, w_x: int, w_y: int):
    return dilate_u16(erode_u16(img, w_x, w_y), w_x, w_y)


def closing_u16(img, w_x: int, w_y: int):
    return erode_u16(dilate_u16(img, w_x, w_y), w_x, w_y)


def vhgw_1d(img, window: int, axis: int, op: str):
    """van Herk/Gil-Werman running min/max — numpy reference of the
    *algorithm* (not just the result), used to cross-check the Pallas vHGW
    kernel's segment decomposition and the rust implementation's logic.

    out[i] = comb(S[i], R[i + w - 1]) over the identity-padded array,
    where R is the per-segment prefix scan and S the per-segment suffix
    scan with segment length ``w``.
    """
    if window % 2 != 1 or window < 1:
        raise ValueError(f"window must be odd and >= 1, got {window}")
    if window == 1:
        return jnp.asarray(img)
    wing = window // 2
    arr = np.asarray(img)
    arr = np.moveaxis(arr, axis, -1)
    n = arr.shape[-1]
    ident = reduction_identity(op, arr.dtype)
    # pad left wing, right wing, then up to a segment multiple
    nseg = -(-(n + 2 * wing) // window)
    total = nseg * window
    padded = np.full(arr.shape[:-1] + (total,), ident, dtype=arr.dtype)
    padded[..., wing : wing + n] = arr
    segs = padded.reshape(arr.shape[:-1] + (nseg, window))
    fn = np.minimum if op == "min" else np.maximum
    r = fn.accumulate(segs, axis=-1)
    s = fn.accumulate(segs[..., ::-1], axis=-1)[..., ::-1]
    r = r.reshape(arr.shape[:-1] + (total,))
    s = s.reshape(arr.shape[:-1] + (total,))
    idx = np.arange(n)
    out = fn(s[..., idx], r[..., idx + window - 1])
    return jnp.asarray(np.moveaxis(out, -1, axis))
