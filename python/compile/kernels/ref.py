"""Pure-jnp reference oracles for the morphology + transpose kernels.

These are the CORE correctness signal: every Pallas kernel in this package
is checked against these functions by pytest (allclose / exact-equal for
integer dtypes).

Conventions (shared by the whole stack — python, HLO artifacts and the
rust native implementations):

* Images are 2-D arrays indexed ``[row, col]`` (= ``[y, x]``).
* A rectangular structuring element of size ``w_x × w_y`` spans ``w_x``
  columns and ``w_y`` rows, anchored at its center; windows are odd
  (``w = 2*wing + 1``).
* Border policy is **identity padding**: out-of-image samples contribute
  the identity of the reduction (``255``/dtype-max for erosion=min,
  ``0``/dtype-min for dilation=max), i.e. the reduction effectively runs
  over the intersection of the window with the image.  Output has the
  same shape as the input.  (The paper "processes edges separately";
  identity padding is the standard way to make that well defined.)

Paper terminology mapping (the paper names passes by their SIMD
iteration direction, which is the *opposite* of the window direction):

* paper "horizontal pass", SE ``1 × w_y``  →  ``min_filter_rows``
  (window spans ``w_y`` ROWS, SIMD runs along contiguous columns).
* paper "vertical pass", SE ``w_x × 1``    →  ``min_filter_cols``
  (window spans ``w_x`` COLUMNS within each row).
"""

import jax.numpy as jnp
import numpy as np


def reduction_identity(op: str, dtype) -> int:
    """Identity element for ``op`` (``"min"`` or ``"max"``) at ``dtype``."""
    if op not in ("min", "max"):
        raise ValueError(f"op must be 'min' or 'max', got {op!r}")
    if jnp.issubdtype(jnp.dtype(dtype), jnp.integer):
        info = jnp.iinfo(dtype)
        return info.max if op == "min" else info.min
    return jnp.inf if op == "min" else -jnp.inf


def _combine(op: str):
    return jnp.minimum if op == "min" else jnp.maximum


def pad_axis(img, wing: int, axis: int, op: str):
    """Pad ``img`` by ``wing`` identity elements on both sides of ``axis``."""
    if wing == 0:
        return img
    pad = [(0, 0)] * img.ndim
    pad[axis] = (wing, wing)
    return jnp.pad(img, pad, constant_values=reduction_identity(op, img.dtype))


def filter_1d(img, window: int, axis: int, op: str):
    """Running min/max of odd ``window`` along ``axis`` (identity borders).

    Direct (O(w)-slices) formulation — the oracle everything else is
    measured against.
    """
    if window % 2 != 1 or window < 1:
        raise ValueError(f"window must be odd and >= 1, got {window}")
    wing = window // 2
    padded = pad_axis(img, wing, axis, op)
    comb = _combine(op)
    n = img.shape[axis]
    out = jnp.take(padded, jnp.arange(0, n), axis=axis)
    for k in range(1, window):
        out = comb(out, jnp.take(padded, jnp.arange(k, k + n), axis=axis))
    return out


def min_filter_rows(img, w_y: int):
    """Paper's *horizontal pass* of erosion: window of ``w_y`` rows."""
    return filter_1d(img, w_y, axis=0, op="min")


def max_filter_rows(img, w_y: int):
    return filter_1d(img, w_y, axis=0, op="max")


def min_filter_cols(img, w_x: int):
    """Paper's *vertical pass* of erosion: window of ``w_x`` columns."""
    return filter_1d(img, w_x, axis=1, op="min")


def max_filter_cols(img, w_x: int):
    return filter_1d(img, w_x, axis=1, op="max")


def erode(img, w_x: int, w_y: int):
    """2-D erosion with a rectangular ``w_x × w_y`` SE (separable form)."""
    return min_filter_cols(min_filter_rows(img, w_y), w_x)


def dilate(img, w_x: int, w_y: int):
    return max_filter_cols(max_filter_rows(img, w_y), w_x)


def erode_nonseparable(img, w_x: int, w_y: int):
    """Direct 2-D sliding-window erosion — used to *prove* separability."""
    wing_x, wing_y = w_x // 2, w_y // 2
    p = pad_axis(pad_axis(img, wing_y, 0, "min"), wing_x, 1, "min")
    h, w = img.shape
    out = None
    for dy in range(w_y):
        for dx in range(w_x):
            tile = p[dy : dy + h, dx : dx + w]
            out = tile if out is None else jnp.minimum(out, tile)
    return out


def dilate_nonseparable(img, w_x: int, w_y: int):
    wing_x, wing_y = w_x // 2, w_y // 2
    p = pad_axis(pad_axis(img, wing_y, 0, "max"), wing_x, 1, "max")
    h, w = img.shape
    out = None
    for dy in range(w_y):
        for dx in range(w_x):
            tile = p[dy : dy + h, dx : dx + w]
            out = tile if out is None else jnp.maximum(out, tile)
    return out


def opening(img, w_x: int, w_y: int):
    return dilate(erode(img, w_x, w_y), w_x, w_y)


def closing(img, w_x: int, w_y: int):
    return erode(dilate(img, w_x, w_y), w_x, w_y)


def gradient(img, w_x: int, w_y: int):
    """Morphological gradient = dilation - erosion (non-negative by
    construction since dilation >= erosion pointwise)."""
    return dilate(img, w_x, w_y) - erode(img, w_x, w_y)


def tophat(img, w_x: int, w_y: int):
    """White top-hat = src - opening (saturating for unsigned dtypes)."""
    o = opening(img, w_x, w_y)
    return jnp.where(img > o, img - o, jnp.zeros_like(img))


def blackhat(img, w_x: int, w_y: int):
    """Black top-hat = closing - src (saturating for unsigned dtypes)."""
    c = closing(img, w_x, w_y)
    return jnp.where(c > img, c - img, jnp.zeros_like(img))


def transpose(img):
    """Matrix/image transpose oracle."""
    return jnp.transpose(img)


# ---------------------------------------------------------------------------
# explicit u16 mirrors
#
# ``filter_1d`` and friends are dtype-generic already; these wrappers pin
# the 16-bit contract the rust stack's ``MorphPixel`` u16 path mirrors
# (identity = 65535/0, dtype preserved end to end) and are what the
# cross-language golden fixture (fixtures/parity_u16.json, generated by
# python/tools/gen_parity_fixture.py) is built from.
# ---------------------------------------------------------------------------


def _as_u16(img):
    img = jnp.asarray(img)
    if img.dtype != jnp.uint16:
        raise ValueError(f"expected a uint16 image, got {img.dtype}")
    return img


def erode_u16(img, w_x: int, w_y: int):
    """2-D u16 erosion (identity borders = 65535), dtype-preserving."""
    out = erode(_as_u16(img), w_x, w_y)
    assert out.dtype == jnp.uint16
    return out


def dilate_u16(img, w_x: int, w_y: int):
    """2-D u16 dilation (identity borders = 0), dtype-preserving."""
    out = dilate(_as_u16(img), w_x, w_y)
    assert out.dtype == jnp.uint16
    return out


def opening_u16(img, w_x: int, w_y: int):
    return dilate_u16(erode_u16(img, w_x, w_y), w_x, w_y)


def closing_u16(img, w_x: int, w_y: int):
    return erode_u16(dilate_u16(img, w_x, w_y), w_x, w_y)


# ---------------------------------------------------------------------------
# scenario-engine mirrors: run-length binary morphology + geodesic
# reconstruction
#
# Loop-exact transcriptions of ``rust/src/morphology/rle.rs`` (per-row
# sorted maximal foreground intervals; erosion/dilation as interval
# arithmetic under identity borders) and ``geodesic.rs`` (reconstruction
# as repeated clamped sweeps, counting every executed sweep *including*
# the final one that proves the fixpoint).  ``test_rle_geodesic.py``
# differential-tests these against the dense oracles above, mirroring
# ``rust/tests/rle_geodesic.rs``.
# ---------------------------------------------------------------------------


def _check_window(window: int, name: str) -> int:
    """``wing_of``: windows are odd and >= 1; returns the wing."""
    if window % 2 != 1 or window < 1:
        raise ValueError(f"{name} must be odd and >= 1, got {window}")
    return window // 2


def rle_encode(img):
    """Per-row sorted maximal foreground runs ``[(start, end), ...]``.

    Mirrors ``RleImage::from_view``: every pixel must be the dtype's
    min or max value (the binary identities); anything else raises —
    the rust side's "stay on the dense path" cue.
    """
    arr = np.asarray(img)
    info = np.iinfo(arr.dtype)
    rows = []
    for row in arr:
        runs = []
        open_s = None
        for x, v in enumerate(row):
            if v == info.max:
                if open_s is None:
                    open_s = x
            elif v == info.min:
                if open_s is not None:
                    runs.append((open_s, x))
                    open_s = None
            else:
                raise ValueError(f"non-binary pixel {v} has no run-length form")
        if open_s is not None:
            runs.append((open_s, len(row)))
        rows.append(runs)
    return rows


def rle_decode(rows, width: int, dtype=np.uint8):
    """Dense image from per-row runs (inverse of ``rle_encode``)."""
    info = np.iinfo(dtype)
    out = np.full((len(rows), width), info.min, dtype=dtype)
    for y, runs in enumerate(rows):
        for s, e in runs:
            out[y, s:e] = info.max
    return jnp.asarray(out)


def _shrink_row(runs, wing: int, width: int):
    """Horizontal erosion of one row's runs: each run loses ``wing`` per
    side, except at a side flush with the image border (identity padding
    is full-foreground there)."""
    if wing == 0:
        return list(runs)
    out = []
    for s, e in runs:
        ns = 0 if s == 0 else s + wing
        ne = width if e == width else max(e - wing, 0)
        if ns < ne:
            out.append((ns, ne))
    return out


def _grow_row(runs, wing: int, width: int):
    """Horizontal dilation: grow each run by ``wing`` per side (clamped
    to the image) and coalesce touching runs."""
    if wing == 0:
        return list(runs)
    out = []
    for s, e in runs:
        ns, ne = max(s - wing, 0), min(e + wing, width)
        if out and ns <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], ne))
        else:
            out.append((ns, ne))
    return out


def _intersect_runs(a, b):
    """Interval intersection of two sorted maximal run lists."""
    i = j = 0
    out = []
    while i < len(a) and j < len(b):
        s = max(a[i][0], b[j][0])
        e = min(a[i][1], b[j][1])
        if s < e:
            out.append((s, e))
        if a[i][1] <= b[j][1]:
            i += 1
        else:
            j += 1
    return out


def _union_runs(lists):
    """Interval union of several sorted run lists (merge + coalesce)."""
    merged = sorted(r for runs in lists for r in runs)
    out = []
    for s, e in merged:
        if out and s <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], e))
        else:
            out.append((s, e))
    return out


def _fold_rows(rows, width: int, wing: int, erode_fold: bool):
    """Vertical pass: output row ``y`` combines the in-image rows
    ``y-wing ..= y+wing`` — intersection for erosion (out-of-image rows
    are the full-foreground identity and drop out), union for dilation."""
    if wing == 0 or not rows:
        return [list(r) for r in rows]
    h = len(rows)
    out = []
    for y in range(h):
        lo, hi = max(y - wing, 0), min(y + wing, h - 1)
        if erode_fold:
            acc = [(0, width)] if width > 0 else []
            for yy in range(lo, hi + 1):
                if not acc:
                    break
                acc = _intersect_runs(acc, rows[yy])
            out.append(acc)
        else:
            out.append(_union_runs(rows[yy] for yy in range(lo, hi + 1)))
    return out


def rle_erode(img, w_x: int, w_y: int):
    """Binary erosion via interval arithmetic — bit-identical to
    ``erode`` on min/max-valued images (``RleImage::erode``)."""
    wing_x = _check_window(w_x, "w_x")
    wing_y = _check_window(w_y, "w_y")
    arr = np.asarray(img)
    width = arr.shape[1] if arr.ndim == 2 else 0
    rows = rle_encode(arr)
    rows = [_shrink_row(r, wing_x, width) for r in rows]
    rows = _fold_rows(rows, width, wing_y, True)
    return rle_decode(rows, width, arr.dtype)


def rle_dilate(img, w_x: int, w_y: int):
    """Binary dilation via interval arithmetic (``RleImage::dilate``)."""
    wing_x = _check_window(w_x, "w_x")
    wing_y = _check_window(w_y, "w_y")
    arr = np.asarray(img)
    width = arr.shape[1] if arr.ndim == 2 else 0
    rows = rle_encode(arr)
    rows = [_grow_row(r, wing_x, width) for r in rows]
    rows = _fold_rows(rows, width, wing_y, False)
    return rle_decode(rows, width, arr.dtype)


def reconstruct_by_dilation(marker, mask, w_x: int, w_y: int):
    """Geodesic reconstruction by dilation: iterate ``min(dilate(cur),
    mask)`` from ``min(marker, mask)`` to stability.

    Returns ``(fixpoint, sweeps)`` with the rust stack's sweep
    accounting (``geodesic::reconstruct_with_plan``): ``sweeps`` counts
    every executed sweep, *including* the final one that proves nothing
    changed.
    """
    marker = jnp.asarray(marker)
    mask = jnp.asarray(mask)
    if marker.shape != mask.shape:
        raise ValueError(f"marker {marker.shape} does not match mask {mask.shape}")
    if 0 in mask.shape:
        return mask, 0
    cur = jnp.minimum(marker, mask)
    sweeps = 0
    while True:
        sweeps += 1
        nxt = jnp.minimum(dilate(cur, w_x, w_y), mask)
        if bool(jnp.array_equal(nxt, cur)):
            return cur, sweeps
        cur = nxt


def reconstruct_by_erosion(marker, mask, w_x: int, w_y: int):
    """Dual reconstruction: iterate ``max(erode(cur), mask)`` from
    ``max(marker, mask)`` to stability; same sweep accounting."""
    marker = jnp.asarray(marker)
    mask = jnp.asarray(mask)
    if marker.shape != mask.shape:
        raise ValueError(f"marker {marker.shape} does not match mask {mask.shape}")
    if 0 in mask.shape:
        return mask, 0
    cur = jnp.maximum(marker, mask)
    sweeps = 0
    while True:
        sweeps += 1
        nxt = jnp.maximum(erode(cur, w_x, w_y), mask)
        if bool(jnp.array_equal(nxt, cur)):
            return cur, sweeps
        cur = nxt


def vhgw_1d(img, window: int, axis: int, op: str):
    """van Herk/Gil-Werman running min/max — numpy reference of the
    *algorithm* (not just the result), used to cross-check the Pallas vHGW
    kernel's segment decomposition and the rust implementation's logic.

    out[i] = comb(S[i], R[i + w - 1]) over the identity-padded array,
    where R is the per-segment prefix scan and S the per-segment suffix
    scan with segment length ``w``.
    """
    if window % 2 != 1 or window < 1:
        raise ValueError(f"window must be odd and >= 1, got {window}")
    if window == 1:
        return jnp.asarray(img)
    wing = window // 2
    arr = np.asarray(img)
    arr = np.moveaxis(arr, axis, -1)
    n = arr.shape[-1]
    ident = reduction_identity(op, arr.dtype)
    # pad left wing, right wing, then up to a segment multiple
    nseg = -(-(n + 2 * wing) // window)
    total = nseg * window
    padded = np.full(arr.shape[:-1] + (total,), ident, dtype=arr.dtype)
    padded[..., wing : wing + n] = arr
    segs = padded.reshape(arr.shape[:-1] + (nseg, window))
    fn = np.minimum if op == "min" else np.maximum
    r = fn.accumulate(segs, axis=-1)
    s = fn.accumulate(segs[..., ::-1], axis=-1)[..., ::-1]
    r = r.reshape(arr.shape[:-1] + (total,))
    s = s.reshape(arr.shape[:-1] + (total,))
    idx = np.arange(n)
    out = fn(s[..., idx], r[..., idx + window - 1])
    return jnp.asarray(np.moveaxis(out, -1, axis))
