"""L2: the separable-morphology compute graph (build-time JAX).

Composes the L1 Pallas kernels (``kernels.morph1d``, ``kernels.transpose``)
into the paper's full operations:

* a 2-D erosion/dilation with a rectangular ``w_x × w_y`` SE is a
  rows-window pass (paper's *horizontal* pass, ``1 × w_y``) followed by a
  cols-window pass (paper's *vertical* pass, ``w_x × 1``);
* the vertical pass has two strategies, exactly as in §5.2 —
  ``"transpose"`` (baseline: transpose ∘ rows-pass ∘ transpose, using the
  tiled transpose kernel) and ``"direct"`` (the linear §5.2.2 form);
* per-pass algorithm choice is ``"linear"``, ``"logtree"``, ``"vhgw"`` or
  ``"hybrid"`` — hybrid applies the paper's §5.3 policy: linear for
  windows up to the crossover (w_y⁰ = 69 / w_x⁰ = 59), vHGW above;
* derived ops (opening, closing, gradient, top-hat, black-hat) are the
  standard compositions over erode/dilate.

Everything here is traced once by ``aot.py`` and shipped to rust as HLO
text; python never runs at serving time.
"""

import jax.numpy as jnp

from .kernels import morph1d
from .kernels import transpose as tk

# Paper §5.3 crossover thresholds (Exynos 5422 measurements).
W_Y0 = 69  # horizontal pass: linear wins for w_y <= 69
W_X0 = 59  # vertical pass:   linear wins for w_x <= 59

PASS_METHODS = ("linear", "logtree", "vhgw", "hybrid")
VERTICAL_STRATEGIES = ("transpose", "direct")
OPS = ("erode", "dilate", "opening", "closing", "gradient", "tophat", "blackhat")


def resolve_method(method: str, window: int, threshold: int) -> str:
    """Resolve ``"hybrid"`` to a concrete kernel for this window size."""
    if method not in PASS_METHODS:
        raise ValueError(f"unknown method {method!r}, want one of {PASS_METHODS}")
    if method != "hybrid":
        return method
    return "linear" if window <= threshold else "vhgw"


def pass_rows(img, w_y: int, op: str, method: str = "hybrid"):
    """Paper's horizontal pass: running ``op`` over ``w_y`` rows."""
    m = resolve_method(method, w_y, W_Y0)
    return morph1d.filter_rows(img, w_y, op, m)


def pass_cols(img, w_x: int, op: str, method: str = "hybrid",
              vertical: str = "transpose"):
    """Paper's vertical pass: running ``op`` over ``w_x`` columns.

    ``vertical="transpose"`` reproduces §5.2.1 (transpose, fast
    rows-pass, transpose back); ``"direct"`` reproduces §5.2.2.
    """
    if vertical not in VERTICAL_STRATEGIES:
        raise ValueError(
            f"unknown vertical strategy {vertical!r}, want one of {VERTICAL_STRATEGIES}"
        )
    m = resolve_method(method, w_x, W_X0)
    if w_x == 1:
        return img
    if vertical == "direct":
        return morph1d.filter_cols(img, w_x, op, m)
    t = tk.transpose_tiled(img)
    t = morph1d.filter_rows(t, w_x, op, m)
    return tk.transpose_tiled(t)


def _morph(img, w_x: int, w_y: int, op: str, method: str, vertical: str):
    out = pass_rows(img, w_y, op, method) if w_y > 1 else img
    return pass_cols(out, w_x, op, method, vertical)


def erode(img, w_x: int, w_y: int, method: str = "hybrid",
          vertical: str = "transpose"):
    """2-D erosion with a ``w_x × w_y`` rectangular SE."""
    return _morph(img, w_x, w_y, "min", method, vertical)


def dilate(img, w_x: int, w_y: int, method: str = "hybrid",
           vertical: str = "transpose"):
    """2-D dilation with a ``w_x × w_y`` rectangular SE."""
    return _morph(img, w_x, w_y, "max", method, vertical)


def opening(img, w_x: int, w_y: int, method: str = "hybrid",
            vertical: str = "transpose"):
    return dilate(erode(img, w_x, w_y, method, vertical), w_x, w_y, method, vertical)


def closing(img, w_x: int, w_y: int, method: str = "hybrid",
            vertical: str = "transpose"):
    return erode(dilate(img, w_x, w_y, method, vertical), w_x, w_y, method, vertical)


def gradient(img, w_x: int, w_y: int, method: str = "hybrid",
             vertical: str = "transpose"):
    """Morphological gradient: dilation − erosion (≥ 0 pointwise)."""
    return dilate(img, w_x, w_y, method, vertical) - erode(
        img, w_x, w_y, method, vertical
    )


def tophat(img, w_x: int, w_y: int, method: str = "hybrid",
           vertical: str = "transpose"):
    """White top-hat: src − opening, saturating for unsigned dtypes."""
    o = opening(img, w_x, w_y, method, vertical)
    return jnp.where(img > o, img - o, jnp.zeros_like(img))


def blackhat(img, w_x: int, w_y: int, method: str = "hybrid",
             vertical: str = "transpose"):
    """Black top-hat: closing − src, saturating for unsigned dtypes."""
    c = closing(img, w_x, w_y, method, vertical)
    return jnp.where(c > img, c - img, jnp.zeros_like(img))


_OP_FNS = {
    "erode": erode,
    "dilate": dilate,
    "opening": opening,
    "closing": closing,
    "gradient": gradient,
    "tophat": tophat,
    "blackhat": blackhat,
}


def op_fn(op: str):
    """Look up the callable for a named op."""
    if op not in _OP_FNS:
        raise ValueError(f"unknown op {op!r}, want one of {sorted(_OP_FNS)}")
    return _OP_FNS[op]


def build_op(op: str, w_x: int, w_y: int, method: str = "hybrid",
             vertical: str = "transpose"):
    """Return ``img -> (result,)`` for a named op with baked-in parameters
    — the unit ``aot.py`` lowers to one HLO artifact (1-tuple output to
    match the rust loader's ``to_tuple1`` convention)."""
    f = op_fn(op)

    def fn(img):
        return (f(img, w_x, w_y, method=method, vertical=vertical),)

    fn.__name__ = f"{op}_w{w_x}x{w_y}"
    return fn


def build_transpose():
    """Return ``img -> (img.T,)`` as a standalone artifact."""

    def fn(img):
        return (tk.transpose_tiled(img),)

    fn.__name__ = "transpose"
    return fn
