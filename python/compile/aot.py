"""AOT compile path: lower every L2 variant to HLO *text* + manifest.

Interchange is HLO text, NOT a serialized ``HloModuleProto``: jax ≥ 0.5
emits protos with 64-bit instruction ids which the rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run once at build time (``make artifacts``)::

    cd python && python -m compile.aot --outdir ../artifacts

Outputs ``<outdir>/<name>.hlo.txt`` per variant plus ``manifest.json``
describing each artifact (op, window, shape, dtype, input/output layout)
for the rust runtime (`rust/src/runtime/manifest.rs`).
"""

import argparse
import hashlib
import json
import pathlib
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

DTYPE = "u8"  # the paper's image type: 8-bit unsigned integer

# Variant grid lowered by default.  Shapes: the paper's 800×600 gray image
# (rows × cols = 600×800) plus a small shape for fast integration tests.
SHAPES = ((600, 800), (256, 256))
OPS = ("erode", "dilate", "opening", "closing", "gradient")
WINDOWS = ((3, 3), (7, 7), (15, 15))
# Reduced grid for --quick (CI / smoke).
QUICK_SHAPES = ((256, 256),)
QUICK_OPS = ("erode", "dilate")
QUICK_WINDOWS = ((3, 3),)


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_fn(fn, height: int, width: int) -> str:
    spec = jax.ShapeDtypeStruct((height, width), jnp.uint8)
    return to_hlo_text(jax.jit(fn).lower(spec))


def variant_name(op: str, h: int, w: int, w_x: int, w_y: int) -> str:
    return f"{op}_{h}x{w}_w{w_x}x{w_y}"


def build_variants(shapes, ops, windows, method: str, vertical: str):
    """Yield (name, fn, metadata) for the full variant grid."""
    for h, w in shapes:
        for op in ops:
            for w_x, w_y in windows:
                name = variant_name(op, h, w, w_x, w_y)
                fn = model.build_op(op, w_x, w_y, method=method, vertical=vertical)
                meta = {
                    "name": name,
                    "kind": "morphology",
                    "op": op,
                    "height": h,
                    "width": w,
                    "w_x": w_x,
                    "w_y": w_y,
                    "method": method,
                    "vertical": vertical,
                    "dtype": DTYPE,
                    "input": {"shape": [h, w], "dtype": DTYPE},
                    "output": {"shape": [h, w], "dtype": DTYPE},
                }
                yield name, fn, meta
        # one standalone transpose artifact per shape
        name = f"transpose_{h}x{w}"
        meta = {
            "name": name,
            "kind": "transpose",
            "op": "transpose",
            "height": h,
            "width": w,
            "w_x": 0,
            "w_y": 0,
            "method": "tiled",
            "vertical": "-",
            "dtype": DTYPE,
            "input": {"shape": [h, w], "dtype": DTYPE},
            "output": {"shape": [w, h], "dtype": DTYPE},
        }
        yield name, model.build_transpose(), meta


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts", help="artifact directory")
    ap.add_argument("--quick", action="store_true", help="reduced variant grid")
    # Default is the optimized log-depth window reduction (L1 perf
    # deliverable): identical results to "linear"/"hybrid" (pytest-proven)
    # with ceil(log2 w)+1 combines instead of w-1 — ~2x fewer vector ops
    # at w=15 (EXPERIMENTS.md §Perf, iteration 4).  Use --method hybrid
    # for the paper-faithful §5.3 dispatch.
    ap.add_argument("--method", default="logtree", choices=model.PASS_METHODS)
    # "direct" avoids lowering two tile-grid transpose pallas_calls per
    # cols pass; under interpret-mode emulation those dominated serving
    # latency (exec p50 33.6 ms -> 0.5 ms on 256x256, EXPERIMENTS.md
    # §Perf iteration 4).
    ap.add_argument("--vertical", default="direct",
                    choices=model.VERTICAL_STRATEGIES)
    args = ap.parse_args(argv)

    outdir = pathlib.Path(args.outdir)
    outdir.mkdir(parents=True, exist_ok=True)

    shapes = QUICK_SHAPES if args.quick else SHAPES
    ops = QUICK_OPS if args.quick else OPS
    windows = QUICK_WINDOWS if args.quick else WINDOWS

    manifest = {"format": 1, "dtype": DTYPE, "artifacts": []}
    t0 = time.time()
    for name, fn, meta in build_variants(shapes, ops, windows,
                                         args.method, args.vertical):
        t = time.time()
        text = lower_fn(fn, meta["height"], meta["width"])
        fname = f"{name}.hlo.txt"
        (outdir / fname).write_text(text)
        meta["file"] = fname
        meta["sha256"] = hashlib.sha256(text.encode()).hexdigest()
        meta["hlo_bytes"] = len(text)
        manifest["artifacts"].append(meta)
        print(f"  lowered {name:<28} {len(text):>9} chars  {time.time()-t:5.1f}s",
              flush=True)

    (outdir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"wrote {len(manifest['artifacts'])} artifacts + manifest.json "
          f"to {outdir} in {time.time()-t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
